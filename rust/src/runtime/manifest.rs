//! Artifact manifest (`artifacts/manifest.json`) — shapes and dtypes of
//! every AOT module plus per-task model metadata, written by aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// dtype + shape of one tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One AOT-lowered module.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Per-task model metadata (the `_spec_<task>` manifest entries).
#[derive(Clone, Debug)]
pub struct TaskModelSpec {
    pub dims: Vec<usize>,
    pub n_params: usize,
    pub d_in: usize,
    pub n_classes: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub chunk: usize,
    pub eval_chunk: usize,
    modules: BTreeMap<String, ModuleSpec>,
    tasks: BTreeMap<String, TaskModelSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let chunk = j
            .get("chunk")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing chunk"))?;
        let eval_chunk = j
            .get("eval_chunk")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing eval_chunk"))?;
        let mods = j
            .get("modules")
            .ok_or_else(|| anyhow!("manifest missing modules"))?;
        let mut modules = BTreeMap::new();
        let mut tasks = BTreeMap::new();
        for name in mods.keys() {
            let entry = mods.get(name).unwrap();
            if let Some(task) = name.strip_prefix("_spec_") {
                tasks.insert(
                    task.to_string(),
                    TaskModelSpec {
                        dims: usize_arr(entry.get("dims"))?,
                        n_params: req_usize(entry, "n_params")?,
                        d_in: req_usize(entry, "d_in")?,
                        n_classes: req_usize(entry, "n_classes")?,
                    },
                );
                continue;
            }
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            modules.insert(
                name.to_string(),
                ModuleSpec {
                    file,
                    inputs: tensor_specs(entry.get("inputs"))?,
                    outputs: tensor_specs(entry.get("outputs"))?,
                },
            );
        }
        Ok(Manifest { chunk, eval_chunk, modules, tasks })
    }

    pub fn module(&self, name: &str) -> Option<&ModuleSpec> {
        self.modules.get(name)
    }

    pub fn task(&self, name: &str) -> Option<&TaskModelSpec> {
        self.tasks.get(name)
    }

    pub fn module_names(&self) -> impl Iterator<Item = &str> {
        self.modules.keys().map(|s| s.as_str())
    }

    /// Batch buckets available for a task's train module, ascending.
    pub fn train_buckets(&self, task: &str) -> Vec<usize> {
        let prefix = format!("train_{task}_b");
        let mut v: Vec<usize> = self
            .modules
            .keys()
            .filter_map(|k| k.strip_prefix(&prefix).and_then(|b| b.parse().ok()))
            .collect();
        v.sort_unstable();
        v
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing {key}"))
}

fn usize_arr(j: Option<&Json>) -> Result<Vec<usize>> {
    j.and_then(Json::as_arr)
        .map(|v| v.iter().filter_map(Json::as_usize).collect())
        .ok_or_else(|| anyhow!("expected usize array"))
}

fn tensor_specs(j: Option<&Json>) -> Result<Vec<TensorSpec>> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("expected tensor spec array"))?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
                shape: usize_arr(t.get("shape"))?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "chunk": 5, "eval_chunk": 256,
      "modules": {
        "train_har_b4": {"file": "train_har_b4.hlo.txt",
          "inputs": [{"dtype": "f32", "shape": [2758]},
                     {"dtype": "f32", "shape": [5, 4, 36]},
                     {"dtype": "i32", "shape": [5, 4]},
                     {"dtype": "f32", "shape": []}],
          "outputs": [{"dtype": "f32", "shape": [2758]},
                      {"dtype": "f32", "shape": []}]},
        "train_har_b16": {"file": "x", "inputs": [], "outputs": []},
        "_spec_har": {"dims": [36, 64, 6], "n_params": 2758,
                      "d_in": 36, "n_classes": 6}
      }
    }"#;

    #[test]
    fn parses_modules_and_tasks() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.chunk, 5);
        assert_eq!(m.eval_chunk, 256);
        let t = m.module("train_har_b4").unwrap();
        assert_eq!(t.inputs.len(), 4);
        assert_eq!(t.inputs[1].shape, vec![5, 4, 36]);
        assert_eq!(t.inputs[2].dtype, "i32");
        assert_eq!(t.outputs[0].shape, vec![2758]);
        let spec = m.task("har").unwrap();
        assert_eq!(spec.dims, vec![36, 64, 6]);
        assert_eq!(spec.n_params, 2758);
        assert!(m.module("_spec_har").is_none());
    }

    #[test]
    fn train_buckets_sorted() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.train_buckets("har"), vec![4, 16]);
        assert!(m.train_buckets("nope").is_empty());
    }

    #[test]
    fn scalar_shape_is_empty_vec() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let t = m.module("train_har_b4").unwrap();
        assert!(t.inputs[3].shape.is_empty());
        let n: usize = t.inputs[3].shape.iter().product();
        assert_eq!(n, 1); // empty product = 1 = scalar element count
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}

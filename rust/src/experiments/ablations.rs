//! Extension ablations beyond the paper's figures — the design knobs
//! §4.1/§4.2 call out but do not sweep:
//!
//! * `ablation-k` — staleness-cluster count K: server-side compression
//!   work (K codec passes per round instead of |N^t|) vs recovery
//!   precision / end metric. §4.1: "K can be adjusted flexibly to
//!   achieve a balance between computational efficiency and model
//!   recovery precision".
//! * `ablation-lambda` — the Eq. 5 importance mix λ between sample
//!   volume and label-distribution closeness.

use anyhow::Result;

use super::{out_dir, render_table, run_all, save_all, write_text, RunSpec};
use crate::compress::caesar_compress;
use crate::config::ExperimentConfig;
use crate::util::cli::Args;

/// K-cluster sweep: end-to-end metric + measured server compression cost.
pub fn run_k_sweep(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("ablations");
    let ks = [1usize, 2, 4, 8, 0]; // 0 = exact per-device ratios
    let mut specs = vec![];
    for &k in &ks {
        let mut cfg = ExperimentConfig::preset(args.get_or("task", "cifar")).apply_overrides(args);
        if args.get_usize("clusters").is_none() {
            cfg.clusters = k;
        }
        specs.push(RunSpec {
            scheme: "caesar".into(),
            cfg,
            suffix: format!("k{k}"),
        });
    }
    println!("[ablation-k] cluster-count sweep K in {{1,2,4,8,exact}}");
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    // measured server-side codec cost per round: K compress passes vs
    // |N^t| passes, on the paper-scale parameter count
    let n = 100_000;
    let w: Vec<f32> = {
        let mut rng = crate::util::rng::Rng::new(11);
        (0..n).map(|_| rng.normal() as f32).collect()
    };
    let t0 = std::time::Instant::now();
    caesar_compress(&w, 0.35);
    let per_pass_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut rows = vec![];
    let mut csv = String::from("k,final,time_s,traffic_gb,server_ms_per_round\n");
    for (s, r) in specs.iter().zip(&results) {
        let k_eff = if s.cfg.clusters == 0 {
            s.cfg.participants_per_round()
        } else {
            s.cfg.clusters.min(s.cfg.participants_per_round())
        };
        let ms = per_pass_ms * k_eff as f64;
        let label = if s.cfg.clusters == 0 { "exact".into() } else { s.cfg.clusters.to_string() };
        rows.push(vec![
            label.clone(),
            format!("{:.4}", r.final_metric(s.cfg.task == "oppo")),
            format!("{:.0}", r.total_time_s()),
            format!("{:.2}", r.total_traffic_gb()),
            format!("{ms:.2}"),
        ]);
        csv.push_str(&format!(
            "{label},{:.4},{:.1},{:.4},{ms:.3}\n",
            r.final_metric(s.cfg.task == "oppo"),
            r.total_time_s(),
            r.total_traffic_gb()
        ));
    }
    let table = render_table(&["K", "final", "time_s", "traffic_GB", "server_ms/round"], &rows);
    println!("{table}");
    write_text(&dir.join("ablation_k.csv"), &csv)?;
    write_text(&dir.join("ablation_k.txt"), &table)?;
    Ok(())
}

/// λ sweep: how the Eq. 5 volume/KL mix affects the end metric.
pub fn run_lambda_sweep(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("ablations");
    let lambdas = [0.0, 0.25, 0.5, 0.75, 1.0];
    let mut specs = vec![];
    for &l in &lambdas {
        let mut cfg = ExperimentConfig::preset(args.get_or("task", "cifar")).apply_overrides(args);
        if args.get_f64("lambda").is_none() {
            cfg.lambda = l;
        }
        specs.push(RunSpec {
            scheme: "caesar".into(),
            cfg,
            suffix: format!("l{}", (l * 100.0) as usize),
        });
    }
    println!("[ablation-lambda] importance mix sweep λ in {{0, .25, .5, .75, 1}}");
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    let mut rows = vec![];
    let mut csv = String::from("lambda,final,traffic_at_target_gb\n");
    for (s, r) in specs.iter().zip(&results) {
        let use_auc = s.cfg.task == "oppo";
        let at = r.time_traffic_at(s.cfg.target_acc, use_auc);
        rows.push(vec![
            format!("{:.2}", s.cfg.lambda),
            format!("{:.4}", r.final_metric(use_auc)),
            at.map_or("-".into(), |(_, g)| format!("{g:.2}")),
        ]);
        csv.push_str(&format!(
            "{:.2},{:.4},{}\n",
            s.cfg.lambda,
            r.final_metric(use_auc),
            at.map_or(String::new(), |(_, g)| format!("{g:.4}"))
        ));
    }
    let table = render_table(&["lambda", "final", "GB@target"], &rows);
    println!("{table}");
    write_text(&dir.join("ablation_lambda.csv"), &csv)?;
    write_text(&dir.join("ablation_lambda.txt"), &table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_args(tmp: &std::path::Path, extra: &str) -> Args {
        Args::parse(
            format!(
                "x out={} task=har rounds=2 n-train=600 tau=2 trainer=native --quiet {extra}",
                tmp.display()
            )
            .split_whitespace()
            .map(String::from),
        )
    }

    #[test]
    fn k_sweep_writes_artifacts() {
        let tmp = std::env::temp_dir().join("caesar_abl_k");
        let _ = std::fs::remove_dir_all(&tmp);
        run_k_sweep(&fast_args(&tmp, "")).unwrap();
        assert!(tmp.join("ablations/ablation_k.csv").exists());
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn lambda_sweep_writes_artifacts() {
        let tmp = std::env::temp_dir().join("caesar_abl_l");
        let _ = std::fs::remove_dir_all(&tmp);
        run_lambda_sweep(&fast_args(&tmp, "")).unwrap();
        let csv =
            std::fs::read_to_string(tmp.join("ablations/ablation_lambda.csv")).unwrap();
        assert_eq!(csv.lines().count(), 6);
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

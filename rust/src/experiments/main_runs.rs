//! Figures 5/6/7 + Table 3 — the head-to-head evaluation: five schemes
//! (FedAvg, FlexCom, ProWD, PyramidFL, Caesar) on the four applications.
//!
//! All four artifacts come from the same 20 runs: Fig 5 is the
//! accuracy-vs-time series, Fig 6 accuracy-vs-traffic, Fig 7 the mean
//! per-round waiting time, Table 3 the traffic/time at the target
//! accuracy (the highest value all schemes reach).

use anyhow::Result;

use super::{out_dir, render_table, run_all, save_all, write_text, RunSpec};
use crate::config::ExperimentConfig;
use crate::coordinator::RunResult;
use crate::schemes::MAIN_SCHEMES;
use crate::util::cli::Args;

/// Tasks of §6.1 in paper order.
pub const TASKS: [&str; 4] = ["cifar", "har", "speech", "oppo"];

pub fn run(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("main");
    let tasks: Vec<&str> = match args.get("task") {
        Some(t) => vec![TASKS.iter().find(|&&x| x == t).copied().unwrap_or("cifar")],
        None => TASKS.to_vec(),
    };
    let mut specs = vec![];
    for task in &tasks {
        let cfg = ExperimentConfig::preset(task).apply_overrides(args);
        for s in MAIN_SCHEMES {
            specs.push(RunSpec { scheme: s.to_string(), cfg: cfg.clone(), suffix: "main".into() });
        }
    }
    println!("[fig5/6/7 + table3] {} runs ({} tasks x {} schemes)", specs.len(), tasks.len(), MAIN_SCHEMES.len());
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    // --- Table 3 ---
    let mut t3_rows = vec![];
    let mut csv = String::from("task,target,scheme,traffic_gb,time_h,final_metric,mean_wait_s\n");
    for task in &tasks {
        let use_auc = *task == "oppo";
        let runs: Vec<(&RunSpec, &RunResult)> = specs
            .iter()
            .zip(&results)
            .filter(|(s, _)| s.cfg.task == *task)
            .collect();
        // target = highest metric achieved by ALL schemes (paper's rule)
        let target = runs
            .iter()
            .map(|(_, r)| r.best_metric(use_auc))
            .fold(f64::MAX, f64::min);
        let target = (target * 100.0).floor() / 100.0;
        for (s, r) in &runs {
            let at = r.time_traffic_at(target, use_auc);
            let (gb, h) = at.map_or((f64::NAN, f64::NAN), |(t, g)| (g, t / 3600.0));
            t3_rows.push(vec![
                task.to_string(),
                format!("{target:.2}"),
                s.scheme.clone(),
                if gb.is_nan() { "-".into() } else { format!("{gb:.2}") },
                if h.is_nan() { "-".into() } else { format!("{h:.2}") },
                format!("{:.4}", r.final_metric(use_auc)),
                format!("{:.2}", r.mean_wait_s()),
            ]);
            csv.push_str(&format!(
                "{task},{target:.2},{},{gb:.4},{h:.4},{:.4},{:.4}\n",
                s.scheme,
                r.final_metric(use_auc),
                r.mean_wait_s()
            ));
        }
    }
    let table = render_table(
        &["task", "target", "scheme", "traffic_GB", "time_h", "final", "wait_s"],
        &t3_rows,
    );
    println!("{table}");
    write_text(&dir.join("table3.csv"), &csv)?;
    write_text(&dir.join("table3.txt"), &table)?;

    // --- Fig 7: mean waiting time per scheme per task ---
    let mut w_csv = String::from("task,scheme,mean_wait_s\n");
    for (s, r) in specs.iter().zip(&results) {
        w_csv.push_str(&format!("{},{},{:.4}\n", s.cfg.task, s.scheme, r.mean_wait_s()));
    }
    write_text(&dir.join("fig7_waiting.csv"), &w_csv)?;
    println!("wrote {}", dir.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_task_fast_run() {
        let tmp = std::env::temp_dir().join("caesar_main_runs");
        let _ = std::fs::remove_dir_all(&tmp);
        let args = Args::parse(
            format!(
                "x out={} task=har rounds=3 n-train=800 tau=3 trainer=native --quiet",
                tmp.display()
            )
            .split_whitespace()
            .map(String::from),
        );
        run(&args).unwrap();
        assert!(tmp.join("main/table3.csv").exists());
        assert!(tmp.join("main/fig7_waiting.csv").exists());
        assert!(tmp.join("main/caesar_har_main.csv").exists());
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

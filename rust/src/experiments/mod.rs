//! Experiment runners — one per paper table/figure (DESIGN.md §5).
//!
//! Every runner writes CSVs under an output directory and prints the same
//! rows/series the paper reports. Absolute numbers are testbed-specific
//! (our testbed is the simulator); the reproduced quantity is the *shape*:
//! ordering, ratios, crossovers. See EXPERIMENTS.md for paper-vs-measured.

pub mod ablations;
pub mod fig1;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod main_runs;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::{RunResult, Server};
use crate::schemes;
use crate::util::cli::Args;
use crate::util::threadpool::{scope_map, workers};

/// A single (scheme, config) run request.
#[derive(Clone)]
pub struct RunSpec {
    pub scheme: String,
    pub cfg: ExperimentConfig,
    /// Filename suffix for the saved CSV/JSON (e.g. "p5", "n200").
    pub suffix: String,
}

/// Execute one run to completion.
pub fn run_one(spec: &RunSpec) -> Result<RunResult> {
    let scheme = schemes::by_name(&spec.scheme)
        .ok_or_else(|| anyhow!("unknown scheme {}", spec.scheme))?;
    let mut srv = Server::new(spec.cfg.clone(), scheme)?;
    srv.run()
}

/// Execute many runs across a thread pool (one server per thread; the PJRT
/// runtime is created inside the worker so it never crosses threads).
/// Progress is printed as runs finish.
pub fn run_all(specs: &[RunSpec], quiet: bool) -> Result<Vec<RunResult>> {
    let n = specs.len();
    let done = std::sync::atomic::AtomicUsize::new(0);
    let results = scope_map(n, workers(n.min(8)), |i| {
        let r = run_one(&specs[i]);
        let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        if !quiet {
            match &r {
                Ok(rr) => eprintln!(
                    "  [{d}/{n}] {}/{} {} done: acc={:.4} traffic={:.3}GB time={:.1}s(sim)",
                    specs[i].scheme,
                    specs[i].cfg.task,
                    specs[i].suffix,
                    rr.final_metric(specs[i].cfg.task == "oppo"),
                    rr.total_traffic_gb(),
                    rr.total_time_s()
                ),
                Err(e) => eprintln!("  [{d}/{n}] {}/{} FAILED: {e:#}", specs[i].scheme, specs[i].cfg.task),
            }
        }
        r
    });
    results.into_iter().collect()
}

/// Save every run's per-round CSV/JSON under `dir`.
pub fn save_all(dir: &Path, specs: &[RunSpec], results: &[RunResult]) -> Result<()> {
    for (s, r) in specs.iter().zip(results) {
        r.save(dir, &s.suffix)?;
    }
    Ok(())
}

/// Output directory from CLI (`out=<dir>`, default `results/`).
pub fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

/// Write a text file, creating parents.
pub fn write_text(path: &Path, text: &str) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(path, text)?;
    Ok(())
}

/// Render an aligned text table (also printed to stdout by runners).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row));
        out.push('\n');
    }
    out
}

/// Run an experiment by name. Known names: fig1, fig1c, fig1d, fig5
/// (= fig6/fig7/table3), fig8, fig9, fig10, table3, all.
pub fn run_by_name(name: &str, args: &Args) -> Result<()> {
    match name {
        "fig1" => fig1::run_prelim(args),
        "fig1c" => fig1::run_fig1c(args),
        "fig1d" => fig1::run_fig1d(args),
        "fig5" | "fig6" | "fig7" | "table3" => main_runs::run(args),
        "fig8" => fig8::run(args),
        "fig9" => fig9::run(args),
        "fig10" => fig10::run(args),
        "ablation-k" => ablations::run_k_sweep(args),
        "ablation-lambda" => ablations::run_lambda_sweep(args),
        "all" => {
            fig1::run_prelim(args)?;
            fig1::run_fig1c(args)?;
            fig1::run_fig1d(args)?;
            main_runs::run(args)?;
            fig8::run(args)?;
            fig9::run(args)?;
            fig10::run(args)
        }
        other => Err(anyhow!("unknown experiment {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionBackend, TrainerBackend};

    pub(crate) fn fast_cfg(task: &str) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(task);
        cfg.trainer = TrainerBackend::Native;
        cfg.compression = CompressionBackend::Native;
        cfg.rounds = 3;
        cfg.n_train = 800;
        cfg.n_test = 200;
        cfg.tau = 3;
        cfg
    }

    #[test]
    fn run_one_and_all() {
        let specs: Vec<RunSpec> = ["fedavg", "caesar"]
            .iter()
            .map(|s| RunSpec {
                scheme: s.to_string(),
                cfg: fast_cfg("har"),
                suffix: "t".into(),
            })
            .collect();
        let results = run_all(&specs, true).unwrap();
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.records.len() == 3));
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["a", "bbbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("bbbb"));
    }

    #[test]
    fn unknown_experiment_errors() {
        let args = Args::parse(std::iter::empty());
        assert!(run_by_name("fig99", &args).is_err());
    }
}

//! Figure 9 — ablation: Caesar vs Caesar-BR (no deviation-aware
//! compression) vs Caesar-DC (no adaptive batch regulation) on CIFAR-10,
//! reporting time- and traffic-to-target plus the derived speedup/saving
//! attributable to each strategy.

use anyhow::Result;

use super::{out_dir, render_table, run_all, save_all, write_text, RunSpec};
use crate::config::ExperimentConfig;
use crate::util::cli::Args;

pub const ABLATIONS: [&str; 3] = ["caesar", "caesar-br", "caesar-dc"];

pub fn run(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("fig9");
    let cfg = ExperimentConfig::preset(args.get_or("task", "cifar")).apply_overrides(args);
    let specs: Vec<RunSpec> = ABLATIONS
        .iter()
        .map(|s| RunSpec { scheme: s.to_string(), cfg: cfg.clone(), suffix: "abl".into() })
        .collect();
    println!("[fig9] ablation on {} ({} rounds)", cfg.task, cfg.rounds);
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    let use_auc = cfg.task == "oppo";
    let target = results
        .iter()
        .map(|r| r.best_metric(use_auc))
        .fold(f64::MAX, f64::min);
    let target = (target * 100.0).floor() / 100.0;
    let mut rows = vec![];
    let mut csv = String::from("scheme,target,time_s,traffic_gb,final\n");
    let mut at: Vec<Option<(f64, f64)>> = vec![];
    for (s, r) in specs.iter().zip(&results) {
        let a = r.time_traffic_at(target, use_auc);
        at.push(a);
        rows.push(vec![
            s.scheme.clone(),
            format!("{target:.2}"),
            a.map_or("-".into(), |(t, _)| format!("{t:.0}")),
            a.map_or("-".into(), |(_, g)| format!("{g:.2}")),
            format!("{:.4}", r.final_metric(use_auc)),
        ]);
        if let Some((t, g)) = a {
            csv.push_str(&format!("{},{target:.2},{t:.1},{g:.4},{:.4}\n", s.scheme, r.final_metric(use_auc)));
        }
    }
    let table = render_table(&["scheme", "target", "time_s", "traffic_GB", "final"], &rows);
    println!("{table}");
    write_text(&dir.join("fig9_ablation.csv"), &csv)?;
    write_text(&dir.join("fig9_ablation.txt"), &table)?;

    // Derived contributions (the paper's 2.07x / 49.38% style numbers)
    if let (Some((t0, g0)), Some((tbr, gbr)), Some((tdc, gdc))) = (at[0], at[1], at[2]) {
        println!(
            "deviation-aware compression: {:.2}x speedup, {:.1}% traffic saving (vs Caesar-BR)",
            tbr / t0,
            100.0 * (1.0 - g0 / gbr)
        );
        println!(
            "batch regulation:            {:.2}x speedup, {:.1}% traffic saving (vs Caesar-DC)",
            tdc / t0,
            100.0 * (1.0 - g0 / gdc)
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_fast_run_writes_csv() {
        let tmp = std::env::temp_dir().join("caesar_fig9");
        let _ = std::fs::remove_dir_all(&tmp);
        let args = Args::parse(
            format!(
                "x out={} task=har rounds=3 n-train=600 tau=3 trainer=native --quiet",
                tmp.display()
            )
            .split_whitespace()
            .map(String::from),
        );
        run(&args).unwrap();
        assert!(tmp.join("fig9/fig9_ablation.txt").exists());
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

//! Figure 10 — scalability: time- and traffic-to-target for the five
//! schemes at device scales 100 / 200 / 300 (CIFAR-10). The paper runs
//! this sweep on a workstation with one Linux process per device; here
//! the fleet simulator scales directly.

use anyhow::Result;

use super::{out_dir, render_table, run_all, save_all, write_text, RunSpec};
use crate::config::ExperimentConfig;
use crate::fleet::FleetKind;
use crate::schemes::MAIN_SCHEMES;
use crate::util::cli::Args;

pub const SCALES: [usize; 3] = [100, 200, 300];

pub fn run(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("fig10");
    let mut specs = vec![];
    for &n in &SCALES {
        let mut cfg = ExperimentConfig::preset("cifar").apply_overrides(args);
        if args.get_usize("devices").is_none() {
            cfg.fleet = FleetKind::JetsonScaled(n);
        }
        for s in MAIN_SCHEMES {
            specs.push(RunSpec { scheme: s.to_string(), cfg: cfg.clone(), suffix: format!("n{n}") });
        }
    }
    println!("[fig10] {} runs (3 scales x 5 schemes)", specs.len());
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    // common target per scale (the paper fixes 80%; we use the highest
    // metric all schemes reach at that scale, capped at the paper's 0.80)
    let mut csv = String::from("devices,scheme,target,time_s,traffic_gb,final\n");
    let mut rows = vec![];
    for &n in &SCALES {
        let runs: Vec<_> = specs
            .iter()
            .zip(&results)
            .filter(|(s, _)| s.suffix == format!("n{n}"))
            .collect();
        let target = runs
            .iter()
            .map(|(_, r)| r.best_metric(false))
            .fold(f64::MAX, f64::min)
            .min(0.80);
        let target = (target * 100.0).floor() / 100.0;
        for (s, r) in runs {
            let at = r.time_traffic_at(target, false);
            rows.push(vec![
                n.to_string(),
                s.scheme.clone(),
                format!("{target:.2}"),
                at.map_or("-".into(), |(t, _)| format!("{t:.0}")),
                at.map_or("-".into(), |(_, g)| format!("{g:.2}")),
                format!("{:.4}", r.final_metric(false)),
            ]);
            if let Some((t, g)) = at {
                csv.push_str(&format!(
                    "{n},{},{target:.2},{t:.1},{g:.4},{:.4}\n",
                    s.scheme,
                    r.final_metric(false)
                ));
            }
        }
    }
    let table =
        render_table(&["devices", "scheme", "target", "time_s", "traffic_GB", "final"], &rows);
    println!("{table}");
    write_text(&dir.join("fig10_scale.csv"), &csv)?;
    write_text(&dir.join("fig10_scale.txt"), &table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_fast_run() {
        let tmp = std::env::temp_dir().join("caesar_fig10");
        let _ = std::fs::remove_dir_all(&tmp);
        let args = Args::parse(
            format!(
                "x out={} rounds=2 n-train=1200 tau=2 trainer=native devices=24 --quiet",
                tmp.display()
            )
            .split_whitespace()
            .map(String::from),
        );
        run(&args).unwrap();
        assert!(tmp.join("fig10/fig10_scale.csv").exists());
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

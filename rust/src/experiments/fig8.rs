//! Figure 8 — final accuracy vs data-heterogeneity level p ∈ {1,2,4,5,10}
//! under a fixed traffic budget, for the five main schemes on CIFAR-10,
//! HAR and Speech; plus the p=1→10 accuracy-degradation summary (Fig 8d).

use anyhow::Result;

use super::{out_dir, render_table, run_all, save_all, write_text, RunSpec};
use crate::config::ExperimentConfig;
use crate::coordinator::RunResult;
use crate::schemes::MAIN_SCHEMES;
use crate::util::cli::Args;

pub const P_LEVELS: [f64; 5] = [1.0, 2.0, 4.0, 5.0, 10.0];
pub const TASKS: [&str; 3] = ["cifar", "har", "speech"];

/// Paper §6.3 traffic budgets (GB): CIFAR 150, HAR 30, Speech 0.3.
fn budget_gb(task: &str) -> f64 {
    match task {
        "cifar" => 150.0,
        "har" => 30.0,
        "speech" => 0.3,
        _ => f64::MAX,
    }
}

/// Accuracy at the traffic budget: last evaluated metric before the
/// cumulative traffic exceeds the budget (final if never exceeded).
pub fn acc_at_budget(r: &RunResult, budget_gb: f64, use_auc: bool) -> f64 {
    let mut best = 0.0f64;
    for rec in &r.records {
        if rec.traffic_gb > budget_gb {
            break;
        }
        if !rec.accuracy.is_nan() {
            best = if use_auc { rec.auc } else { rec.accuracy };
        }
    }
    best
}

pub fn run(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("fig8");
    let tasks: Vec<&str> = match args.get("task") {
        Some(t) => vec![TASKS.iter().find(|&&x| x == t).copied().unwrap_or("cifar")],
        None => TASKS.to_vec(),
    };
    let mut specs = vec![];
    for task in &tasks {
        for &p in &P_LEVELS {
            let mut cfg = ExperimentConfig::preset(task).apply_overrides(args);
            if args.get_f64("p").is_none() {
                cfg.het_p = p;
            }
            for s in MAIN_SCHEMES {
                specs.push(RunSpec {
                    scheme: s.to_string(),
                    cfg: cfg.clone(),
                    suffix: format!("p{}", p as usize),
                });
            }
        }
    }
    println!("[fig8] {} runs (tasks x p-levels x schemes)", specs.len());
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    let mut csv = String::from("task,p,scheme,acc_at_budget\n");
    let mut rows = vec![];
    for (s, r) in specs.iter().zip(&results) {
        let acc = acc_at_budget(r, budget_gb(&s.cfg.task), s.cfg.task == "oppo");
        csv.push_str(&format!("{},{},{},{acc:.4}\n", s.cfg.task, s.cfg.het_p, s.scheme));
        rows.push(vec![
            s.cfg.task.clone(),
            format!("{}", s.cfg.het_p),
            s.scheme.clone(),
            format!("{acc:.4}"),
        ]);
    }
    write_text(&dir.join("fig8_acc.csv"), &csv)?;
    println!("{}", render_table(&["task", "p", "scheme", "acc@budget"], &rows));

    // Fig 8d: degradation from p=1 to p=10 per scheme (averaged over tasks)
    let mut d_rows = vec![];
    let mut d_csv = String::from("scheme,acc_p1,acc_p10,degradation\n");
    for s in MAIN_SCHEMES {
        let acc_at_p = |p: f64| {
            let xs: Vec<f64> = specs
                .iter()
                .zip(&results)
                .filter(|(sp, _)| sp.scheme == s && (sp.cfg.het_p - p).abs() < 1e-9)
                .map(|(sp, r)| acc_at_budget(r, budget_gb(&sp.cfg.task), false))
                .collect();
            crate::util::stats::mean(&xs)
        };
        let (a1, a10) = (acc_at_p(1.0), acc_at_p(10.0));
        d_csv.push_str(&format!("{s},{a1:.4},{a10:.4},{:.4}\n", a1 - a10));
        d_rows.push(vec![
            s.to_string(),
            format!("{a1:.4}"),
            format!("{a10:.4}"),
            format!("{:.4}", a1 - a10),
        ]);
    }
    write_text(&dir.join("fig8d_degradation.csv"), &d_csv)?;
    println!(
        "[fig8d] accuracy degradation p=1 -> p=10:\n{}",
        render_table(&["scheme", "acc@p1", "acc@p10", "drop"], &d_rows)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::RoundRecord;

    fn rec(t: usize, gb: f64, acc: f64) -> RoundRecord {
        RoundRecord {
            t,
            sim_time_s: t as f64,
            traffic_gb: gb,
            accuracy: acc,
            auc: acc,
            ..Default::default()
        }
    }

    #[test]
    fn acc_at_budget_stops_at_budget() {
        let r = RunResult {
            scheme: "x".into(),
            task: "cifar".into(),
            seed: 0,
            records: vec![rec(1, 1.0, 0.3), rec(2, 2.0, 0.5), rec(3, 5.0, 0.9)],
            reached_target: None,
            target: 0.8,
        };
        assert_eq!(acc_at_budget(&r, 2.5, false), 0.5);
        assert_eq!(acc_at_budget(&r, 10.0, false), 0.9);
        assert_eq!(acc_at_budget(&r, 0.5, false), 0.0);
    }
}

//! Figure 1 — the preliminary experiments motivating Caesar.
//!
//! * Fig 1a/1b: No-Compression vs GM/LG × FIC/CAC on CIFAR-10 — training
//!   curves and traffic to reach the common-achievable target accuracy.
//! * Fig 1c: initial-model error vs local-model staleness × model
//!   compression ratio (the model-obsolescence phenomenon).
//! * Fig 1d: device importance (Eq. 5) vs the gradient compression ratio
//!   CAC assigns — showing CAC over-compresses important devices.

use anyhow::Result;

use super::{out_dir, render_table, run_all, save_all, write_text, RunSpec};
use crate::compress::{caesar_compress, caesar_recover};
use crate::config::ExperimentConfig;
use crate::coordinator::Server;
use crate::schemes::{self, RoundCtx};
use crate::util::cli::Args;
use crate::util::stats;

/// The five Fig. 1a schemes.
pub const PRELIM_SCHEMES: [&str; 5] = ["nocomp", "gm-fic", "gm-cac", "lg-fic", "lg-cac"];

/// Fig 1a (training curves) + Fig 1b (traffic at the common target).
pub fn run_prelim(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("fig1");
    let base = ExperimentConfig::preset("cifar").apply_overrides(args);
    let specs: Vec<RunSpec> = PRELIM_SCHEMES
        .iter()
        .map(|s| RunSpec { scheme: s.to_string(), cfg: base.clone(), suffix: "prelim".into() })
        .collect();
    println!("[fig1a/1b] {} prelim runs on cifar ({} rounds)", specs.len(), base.rounds);
    let results = run_all(&specs, args.has_flag("quiet"))?;
    save_all(&dir, &specs, &results)?;

    // Fig 1b: traffic to the highest accuracy every scheme reaches.
    let common = results
        .iter()
        .map(|r| r.best_metric(false))
        .fold(f64::MAX, f64::min);
    let target = (common * 100.0).floor() / 100.0;
    let mut rows = vec![];
    for (s, r) in specs.iter().zip(&results) {
        let at = r.time_traffic_at(target, false);
        rows.push(vec![
            s.scheme.clone(),
            format!("{:.4}", r.final_metric(false)),
            format!("{:.2}", r.total_time_s() / 3600.0),
            at.map_or("-".into(), |(_, gb)| format!("{gb:.2}")),
            at.map_or("-".into(), |(t, _)| format!("{:.2}", t / 3600.0)),
        ]);
    }
    let table = render_table(
        &["scheme", "final_acc", "total_h", &format!("GB@{target:.2}"), &format!("h@{target:.2}")],
        &rows,
    );
    println!("{table}");
    write_text(&dir.join("fig1b_summary.txt"), &table)?;
    Ok(())
}

/// Fig 1c: normalized init-model MSE over (staleness δ, compression ratio θ).
///
/// We train an uncompressed FL run, snapshot the global model each round,
/// then for each (δ, θ): compress the final global model at ratio θ and
/// recover it against the snapshot from δ rounds earlier.
pub fn run_fig1c(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("fig1");
    let mut cfg = ExperimentConfig::preset("cifar").apply_overrides(args);
    if args.get_usize("rounds").is_none() {
        cfg.rounds = 60; // enough drift history for δ ≤ 50
    }
    cfg.eval_every = cfg.rounds; // only the final eval matters here
    let mut srv = Server::new(cfg.clone(), schemes::by_name("nocomp").unwrap())?;
    let mut snaps: Vec<Vec<f32>> = Vec::with_capacity(cfg.rounds + 1);
    snaps.push(srv.global.clone());
    for t in 1..=cfg.rounds {
        srv.step(t)?;
        snaps.push(srv.global.clone());
    }
    let latest = snaps.last().unwrap().clone();

    let stalenesses: [usize; 5] = [1, 5, 10, 25, 50];
    let ratios = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
    let mut grid: Vec<(usize, f64, f64)> = vec![];
    for &d in &stalenesses {
        let local = &snaps[cfg.rounds - d.min(cfg.rounds)];
        for &r in &ratios {
            let cm = caesar_compress(&latest, r);
            let rec = caesar_recover(&cm, local);
            grid.push((d, r, stats::mse(&rec, &latest)));
        }
    }
    // normalize to [0, 1] like the paper's plot
    let max = grid.iter().map(|x| x.2).fold(f64::MIN, f64::max).max(1e-30);
    let mut csv = String::from("staleness,ratio,norm_mse\n");
    let mut rows = vec![];
    for &(d, r, e) in &grid {
        csv.push_str(&format!("{d},{r},{:.6}\n", e / max));
        if (r - 0.6).abs() < 1e-9 || (r - 0.1).abs() < 1e-9 {
            rows.push(vec![d.to_string(), format!("{r:.1}"), format!("{:.4}", e / max)]);
        }
    }
    write_text(&dir.join("fig1c_grid.csv"), &csv)?;
    let table = render_table(&["staleness", "ratio", "norm_mse"], &rows);
    println!("[fig1c] initial-model error (normalized MSE):\n{table}");

    // the paper's qualitative claims, asserted here as a smoke check
    let at = |d: usize, r: f64| {
        grid.iter()
            .find(|&&(dd, rr, _)| dd == d && (rr - r).abs() < 1e-9)
            .unwrap()
            .2
    };
    debug_assert!(at(50, 0.6) > at(1, 0.6));
    debug_assert!(at(50, 0.6) > at(50, 0.1));
    Ok(())
}

/// Fig 1d: per-device importance (Eq. 5) vs the CAC-assigned gradient
/// compression ratio, plus Caesar's rank-based assignment for contrast.
pub fn run_fig1d(args: &Args) -> Result<()> {
    let dir = out_dir(args).join("fig1");
    let cfg = ExperimentConfig::preset("cifar").apply_overrides(args);
    let srv = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap())?;
    let table = srv.importance_table();

    // one synchronized bandwidth draw across the whole fleet
    let fleet = crate::fleet::Fleet::new(cfg.fleet, cfg.seed ^ 0x1D);
    let n = fleet.len();
    let mut beta_u = Vec::with_capacity(n);
    {
        let crate::fleet::Fleet { devices, bandwidth } = &fleet;
        for (i, d) in devices.iter().enumerate() {
            let mut rng = crate::util::rng::Rng::stream(cfg.seed ^ 0x1D, 1, i as u64);
            beta_u.push(d.draw_bandwidth(bandwidth, &mut rng).1);
        }
    }
    let mut csv = String::from("device,importance,cac_ratio,caesar_ratio\n");
    let mut cac_of_important = vec![];
    let mut cac_of_rest = vec![];
    let mut scores: Vec<f64> = (0..n).map(|i| table.upload_ratio(i, 0.0, 1.0)).collect();
    scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for i in 0..n {
        let imp = {
            // reconstruct C_i ∈ [0,1] ordering from the table's rank-ratio
            1.0 - table.upload_ratio(i, 0.0, 1.0)
        };
        let frac = RoundCtx::norm_frac(&beta_u, beta_u[i]);
        let cac = cfg.theta_max - (cfg.theta_max - cfg.theta_min) * frac;
        let caesar = table.upload_ratio(i, cfg.theta_min, cfg.theta_max);
        csv.push_str(&format!("{i},{imp:.4},{cac:.4},{caesar:.4}\n"));
        if imp > 0.75 {
            cac_of_important.push(cac);
        } else {
            cac_of_rest.push(cac);
        }
    }
    write_text(&dir.join("fig1d_scatter.csv"), &csv)?;
    let mi = stats::mean(&cac_of_important);
    let mr = stats::mean(&cac_of_rest);
    println!(
        "[fig1d] mean CAC gradient ratio — top-quartile-importance devices: {mi:.3}, rest: {mr:.3}"
    );
    println!("        (CAC is blind to importance: the two are statistically equal,");
    println!("         so important gradients are routinely over-compressed)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_args(tmp: &str) -> Args {
        Args::parse(
            format!("x out={tmp} rounds=4 n-train=800 tau=3 trainer=native --quiet")
                .split_whitespace()
                .map(String::from),
        )
    }

    #[test]
    fn fig1c_writes_grid() {
        let tmp = std::env::temp_dir().join("caesar_fig1c");
        let _ = std::fs::remove_dir_all(&tmp);
        let args = fast_args(tmp.to_str().unwrap());
        run_fig1c(&args).unwrap();
        let csv = std::fs::read_to_string(tmp.join("fig1/fig1c_grid.csv")).unwrap();
        assert!(csv.lines().count() > 10);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn fig1d_writes_scatter() {
        let tmp = std::env::temp_dir().join("caesar_fig1d");
        let _ = std::fs::remove_dir_all(&tmp);
        let args = fast_args(tmp.to_str().unwrap());
        run_fig1d(&args).unwrap();
        let csv = std::fs::read_to_string(tmp.join("fig1/fig1d_scatter.csv")).unwrap();
        assert_eq!(csv.lines().count(), 81); // header + 80 devices
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

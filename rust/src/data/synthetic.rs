//! Synthetic classification task generators — stand-ins for the paper's
//! four applications (DESIGN.md §Substitutions maps each).
//!
//! Each class is a mixture of `subclusters` Gaussian blobs on a
//! hypersphere; `noise` controls class overlap (and therefore the
//! achievable accuracy ceiling) and `label_noise` flips a fraction of
//! labels, so the learning curves saturate the way real tasks do instead
//! of snapping to 100%.

use crate::util::rng::Rng;

/// Static description of a synthetic task.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_classes: usize,
    pub d: usize,
    /// Gaussian blobs per class.
    pub subclusters: usize,
    /// Class-center radius (separation scale).
    pub radius: f64,
    /// Within-blob feature noise sigma.
    pub noise: f64,
    /// Fraction of labels flipped to a random class.
    pub label_noise: f64,
    /// Seed offset so each task has its own geometry.
    pub geometry_seed: u64,
}

impl TaskSpec {
    /// CIFAR-10 stand-in: 10 classes, 64-dim features.
    pub fn cifar_like() -> TaskSpec {
        TaskSpec {
            name: "cifar",
            n_classes: 10,
            d: 64,
            subclusters: 3,
            radius: 3.0,
            noise: 0.75,
            label_noise: 0.04,
            geometry_seed: 101,
        }
    }

    /// HAR stand-in: 6 classes, 36-dim sensor-like features (easier task —
    /// the paper reaches 86% quickly on HAR).
    pub fn har_like() -> TaskSpec {
        TaskSpec {
            name: "har",
            n_classes: 6,
            d: 36,
            subclusters: 2,
            radius: 2.35,
            noise: 0.62,
            label_noise: 0.03,
            geometry_seed: 202,
        }
    }

    /// Google-Speech stand-in: 35 keyword classes, 40-dim MFCC-like features.
    pub fn speech_like() -> TaskSpec {
        TaskSpec {
            name: "speech",
            n_classes: 35,
            d: 40,
            subclusters: 2,
            radius: 3.5,
            noise: 0.64,
            label_noise: 0.03,
            geometry_seed: 303,
        }
    }

    /// OPPO-TS stand-in: binary click prediction, 128 sparse-ish features.
    pub fn oppo_like() -> TaskSpec {
        TaskSpec {
            name: "oppo",
            n_classes: 2,
            d: 128,
            subclusters: 2,
            radius: 1.05,
            noise: 1.1,
            label_noise: 0.08,
            geometry_seed: 404,
        }
    }

    pub fn by_name(name: &str) -> Option<TaskSpec> {
        match name {
            "cifar" => Some(TaskSpec::cifar_like()),
            "har" => Some(TaskSpec::har_like()),
            "speech" => Some(TaskSpec::speech_like()),
            "oppo" => Some(TaskSpec::oppo_like()),
            _ => None,
        }
    }
}

/// A fully materialized dataset: row-major features + labels.
#[derive(Clone)]
pub struct Dataset {
    pub d: usize,
    pub n_classes: usize,
    pub features: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Generate `n` samples. The class/blob geometry depends only on
    /// `spec.geometry_seed`, so train and test sets generated with
    /// different `rng`s share the same underlying task.
    pub fn generate(spec: &TaskSpec, n: usize, rng: &mut Rng) -> Dataset {
        // Deterministic geometry: centers drawn from a dedicated rng.
        let mut geo = Rng::new(spec.geometry_seed ^ 0x5EED_0F_6E0);
        let mut centers = vec![0.0f64; spec.n_classes * spec.subclusters * spec.d];
        for c in centers.iter_mut() {
            *c = geo.normal();
        }
        // normalize each blob center to `radius`
        for b in 0..spec.n_classes * spec.subclusters {
            let s = &mut centers[b * spec.d..(b + 1) * spec.d];
            let norm = s.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
            for x in s.iter_mut() {
                *x *= spec.radius / norm;
            }
        }
        let mut features = Vec::with_capacity(n * spec.d);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(spec.n_classes);
            let sub = rng.below(spec.subclusters);
            let base = (class * spec.subclusters + sub) * spec.d;
            for j in 0..spec.d {
                let x = centers[base + j] + spec.noise * rng.normal();
                features.push(x as f32);
            }
            let label = if rng.f64() < spec.label_noise {
                rng.below(spec.n_classes)
            } else {
                class
            };
            labels.push(label as u8);
        }
        Dataset { d: spec.d, n_classes: spec.n_classes, features, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes_and_label_range() {
        let mut rng = Rng::new(0);
        let spec = TaskSpec::speech_like();
        let ds = Dataset::generate(&spec, 1000, &mut rng);
        assert_eq!(ds.len(), 1000);
        assert_eq!(ds.features.len(), 1000 * spec.d);
        assert!(ds.labels.iter().all(|&l| (l as usize) < spec.n_classes));
    }

    #[test]
    fn classes_roughly_balanced() {
        let mut rng = Rng::new(1);
        let spec = TaskSpec::cifar_like();
        let ds = Dataset::generate(&spec, 20_000, &mut rng);
        let mut counts = vec![0usize; spec.n_classes];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / 20_000.0;
            assert!((frac - 0.1).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn geometry_shared_between_train_and_test() {
        let spec = TaskSpec::har_like();
        let mut r1 = Rng::new(10);
        let mut r2 = Rng::new(20);
        let train = Dataset::generate(&spec, 3000, &mut r1);
        let test = Dataset::generate(&spec, 3000, &mut r2);
        // nearest-centroid classifier trained on `train` should beat chance
        // on `test` by a wide margin if the geometry is shared.
        let d = spec.d;
        let mut cent = vec![0.0f64; spec.n_classes * d];
        let mut cnt = vec![0usize; spec.n_classes];
        for i in 0..train.len() {
            let l = train.labels[i] as usize;
            cnt[l] += 1;
            for j in 0..d {
                cent[l * d + j] += train.features[i * d + j] as f64;
            }
        }
        for l in 0..spec.n_classes {
            for j in 0..d {
                cent[l * d + j] /= cnt[l].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let mut best = (f64::MAX, 0usize);
            for l in 0..spec.n_classes {
                let dist: f64 = (0..d)
                    .map(|j| {
                        let diff = test.features[i * d + j] as f64 - cent[l * d + j];
                        diff * diff
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, l);
                }
            }
            if best.1 == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        // sub-cluster structure intentionally defeats a single-centroid
        // classifier; well above 6-class chance proves shared geometry
        assert!(acc > 0.35, "nearest-centroid acc={acc} (chance=0.167)");
    }

    #[test]
    fn different_seeds_give_different_samples() {
        let spec = TaskSpec::oppo_like();
        let a = Dataset::generate(&spec, 100, &mut Rng::new(1));
        let b = Dataset::generate(&spec, 100, &mut Rng::new(2));
        assert_ne!(a.features, b.features);
    }

    #[test]
    fn all_four_tasks_generate() {
        for name in ["cifar", "har", "speech", "oppo"] {
            let spec = TaskSpec::by_name(name).unwrap();
            let ds = Dataset::generate(&spec, 64, &mut Rng::new(3));
            assert_eq!(ds.len(), 64);
            assert!(ds.features.iter().all(|x| x.is_finite()));
        }
        assert!(TaskSpec::by_name("nope").is_none());
    }
}

//! Dirichlet non-IID partitioning (paper §6.1, following Hsu et al.).
//!
//! Each device's class distribution is drawn from Dir(δ·q) with q the
//! uniform prior and δ = 1/p; per-device volumes are drawn from a second
//! Dirichlet whose concentration also shrinks with p, so higher p means
//! both stronger label skew and stronger volume skew — exactly the paper's
//! "given p > 0, both data volume and data distribution will be various".
//! p == 0 is the special IID case with identical volumes.

use super::synthetic::Dataset;
use super::Shard;
use crate::util::rng::Rng;

/// Result of a partition: one shard per device.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Shard>,
    /// The drawn per-device class distributions (diagnostics / tests).
    pub class_dists: Vec<Vec<f64>>,
}

/// Partition `ds` across `n_devices` with heterogeneity level `p` (>= 0).
pub fn partition(ds: &Dataset, n_devices: usize, p: f64, rng: &mut Rng) -> Partition {
    assert!(n_devices > 0);
    let n = ds.len();
    let h = ds.n_classes;

    // Pools of sample indices per class, shuffled.
    let mut pools: Vec<Vec<usize>> = vec![vec![]; h];
    for (i, &l) in ds.labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    for pool in pools.iter_mut() {
        rng.shuffle(pool);
    }

    // Target volumes.
    let volumes: Vec<usize> = if p <= 0.0 {
        let base = n / n_devices;
        (0..n_devices)
            .map(|i| base + usize::from(i < n % n_devices))
            .collect()
    } else {
        // volume weights ~ Dir(20/p): mild skew at p=1, heavy at p=10
        let conc = (20.0 / p).max(0.05);
        let w = rng.dirichlet_sym(conc, n_devices);
        let mut v: Vec<usize> = w.iter().map(|&x| (x * n as f64) as usize).collect();
        // fix rounding so volumes sum to n and every device has >= 2 samples
        let mut assigned: usize = v.iter().sum();
        let mut i = 0;
        while assigned < n {
            v[i % n_devices] += 1;
            assigned += 1;
            i += 1;
        }
        for vi in v.iter_mut() {
            if *vi < 2 {
                *vi = 2;
            }
        }
        v
    };

    // Per-device class distributions.
    let delta = if p <= 0.0 { f64::INFINITY } else { 1.0 / p };
    let class_dists: Vec<Vec<f64>> = (0..n_devices)
        .map(|_| {
            if delta.is_infinite() {
                vec![1.0 / h as f64; h]
            } else {
                rng.dirichlet_sym(delta, h)
            }
        })
        .collect();

    // Greedy assignment: each device draws from its class distribution,
    // falling back to the globally fullest pool when its class is empty.
    let mut shards: Vec<Shard> = (0..n_devices)
        .map(|_| Shard { indices: vec![] })
        .collect();
    for dev in 0..n_devices {
        let dist = &class_dists[dev];
        for _ in 0..volumes[dev] {
            let mut class = rng.categorical(dist);
            if pools[class].is_empty() {
                // fullest pool fallback keeps total assignment feasible
                match (0..h).max_by_key(|&c| pools[c].len()) {
                    Some(c) if !pools[c].is_empty() => class = c,
                    _ => break, // everything exhausted
                }
            }
            shards[dev].indices.push(pools[class].pop().unwrap());
        }
    }
    // The min-volume bump can over-commit the sample budget, leaving late
    // devices empty once the pools drain. Every device must hold data
    // (Eq. 2 needs a batch), so re-balance from the largest shard.
    for dev in 0..n_devices {
        if shards[dev].indices.is_empty() {
            let donor = (0..n_devices)
                .max_by_key(|&i| shards[i].indices.len())
                .unwrap();
            if shards[donor].indices.len() >= 2 {
                let moved = shards[donor].indices.pop().unwrap();
                shards[dev].indices.push(moved);
            }
        }
    }
    Partition { shards, class_dists }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::TaskSpec;
    use crate::util::stats;

    fn dataset(n: usize) -> Dataset {
        Dataset::generate(&TaskSpec::cifar_like(), n, &mut Rng::new(99))
    }

    #[test]
    fn covers_every_sample_at_most_once() {
        let ds = dataset(5000);
        let part = partition(&ds, 40, 5.0, &mut Rng::new(0));
        let mut seen = vec![false; ds.len()];
        for s in &part.shards {
            for &i in &s.indices {
                assert!(!seen[i], "sample {i} assigned twice");
                seen[i] = true;
            }
        }
        let total: usize = part.shards.iter().map(|s| s.len()).sum();
        assert!(total as f64 > 0.95 * ds.len() as f64);
    }

    #[test]
    fn iid_partition_is_balanced() {
        let ds = dataset(4000);
        let part = partition(&ds, 40, 0.0, &mut Rng::new(1));
        for s in &part.shards {
            assert_eq!(s.len(), 100);
        }
        // label distributions near-uniform
        let avg_kl: f64 = part
            .shards
            .iter()
            .map(|s| s.kl_from_uniform(&ds))
            .sum::<f64>()
            / 40.0;
        assert!(avg_kl < 0.15, "avg_kl={avg_kl}");
    }

    #[test]
    fn heterogeneity_increases_with_p() {
        let ds = dataset(8000);
        let kl_at = |p: f64| {
            let part = partition(&ds, 40, p, &mut Rng::new(2));
            part.shards
                .iter()
                .map(|s| s.kl_from_uniform(&ds))
                .sum::<f64>()
                / 40.0
        };
        let (k1, k5, k10) = (kl_at(1.0), kl_at(5.0), kl_at(10.0));
        assert!(k1 < k5 && k5 < k10, "kl: p1={k1} p5={k5} p10={k10}");
    }

    #[test]
    fn volume_skew_increases_with_p() {
        let ds = dataset(8000);
        let cv_at = |p: f64| {
            let part = partition(&ds, 40, p, &mut Rng::new(3));
            let vols: Vec<f64> = part.shards.iter().map(|s| s.len() as f64).collect();
            stats::std_dev(&vols) / stats::mean(&vols)
        };
        assert!(cv_at(1.0) < cv_at(10.0));
    }

    #[test]
    fn every_device_gets_samples() {
        let ds = dataset(3000);
        for p in [0.0, 1.0, 10.0] {
            let part = partition(&ds, 80, p, &mut Rng::new(4));
            for (i, s) in part.shards.iter().enumerate() {
                assert!(!s.is_empty(), "device {i} empty at p={p}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = dataset(2000);
        let a = partition(&ds, 20, 5.0, &mut Rng::new(7));
        let b = partition(&ds, 20, 5.0, &mut Rng::new(7));
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.indices, y.indices);
        }
    }
}

//! Datasets and non-IID partitioning.
//!
//! The paper evaluates on CIFAR-10, HAR, Google-Speech and the proprietary
//! OPPO-TS click log — none of which are available here (repro gate). Per
//! the substitution rule we generate synthetic classification tasks with
//! matched *statistical* structure (class counts, volume, Dirichlet non-IID
//! partition) so every studied effect — label skew, volume skew, staleness,
//! compression deviation — exercises the same code paths with real SGD
//! training. See DESIGN.md §Substitutions.

pub mod dirichlet;
pub mod synthetic;

pub use dirichlet::{partition, Partition};
pub use synthetic::{Dataset, TaskSpec};

use crate::util::stats;

/// Per-device view into a dataset: indices into the parent `Dataset`.
#[derive(Clone, Debug)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Label proportion vector over `n_classes` (Eq. 4's Φ_i).
    pub fn label_distribution(&self, ds: &Dataset) -> Vec<f64> {
        let mut counts = vec![0usize; ds.n_classes];
        for &i in &self.indices {
            counts[ds.labels[i] as usize] += 1;
        }
        let total = self.indices.len().max(1) as f64;
        counts.iter().map(|&c| c as f64 / total).collect()
    }

    /// KL(Φ_i || uniform) — the paper's distribution-gap D_i (Eq. 4).
    pub fn kl_from_uniform(&self, ds: &Dataset) -> f64 {
        let p = self.label_distribution(ds);
        let q = vec![1.0 / ds.n_classes as f64; ds.n_classes];
        stats::kl_divergence(&p, &q)
    }

    /// Copy a batch (features flattened row-major + labels) given batch
    /// element positions within this shard.
    pub fn gather(&self, ds: &Dataset, positions: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let d = ds.d;
        let mut xs = Vec::with_capacity(positions.len() * d);
        let mut ys = Vec::with_capacity(positions.len());
        for &p in positions {
            let i = self.indices[p];
            xs.extend_from_slice(&ds.features[i * d..(i + 1) * d]);
            ys.push(ds.labels[i] as i32);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn shard_label_distribution_sums_to_one() {
        let mut rng = Rng::new(0);
        let ds = Dataset::generate(&TaskSpec::cifar_like(), 500, &mut rng);
        let shard = Shard { indices: (0..100).collect() };
        let p = shard.label_distribution(&ds);
        assert_eq!(p.len(), ds.n_classes);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gather_shapes() {
        let mut rng = Rng::new(1);
        let ds = Dataset::generate(&TaskSpec::har_like(), 100, &mut rng);
        let shard = Shard { indices: (0..50).collect() };
        let (xs, ys) = shard.gather(&ds, &[0, 3, 7]);
        assert_eq!(xs.len(), 3 * ds.d);
        assert_eq!(ys.len(), 3);
        assert_eq!(&xs[..ds.d], &ds.features[..ds.d]);
    }

    #[test]
    fn kl_uniform_zero_for_balanced_shard() {
        let mut rng = Rng::new(2);
        let ds = Dataset::generate(&TaskSpec::har_like(), 600, &mut rng);
        // construct a perfectly balanced shard: equal count per class
        let mut per_class: Vec<Vec<usize>> = vec![vec![]; ds.n_classes];
        for (i, &l) in ds.labels.iter().enumerate() {
            per_class[l as usize].push(i);
        }
        let m = per_class.iter().map(|v| v.len()).min().unwrap().min(10);
        let mut idx = vec![];
        for c in &per_class {
            idx.extend_from_slice(&c[..m]);
        }
        let shard = Shard { indices: idx };
        assert!(shard.kl_from_uniform(&ds) < 1e-9);
    }
}

//! Offline stub of the `xla` PJRT bindings.
//!
//! The real three-layer path executes AOT HLO artifacts through
//! `xla_extension` (a native C++ library). That toolchain is not present
//! in the offline build image, so this crate provides the exact API
//! surface `caesar_fl::runtime` consumes, with one behavioral rule:
//!
//! * [`Literal`] is fully functional (host-side tensor container), so the
//!   literal helpers and their tests work everywhere;
//! * every PJRT entry point ([`PjRtClient::cpu`] first of all) returns
//!   [`XlaError`] — `Runtime::open` then fails, `artifacts_available()`
//!   style guards report false, and every XLA-gated test/bench skips
//!   cleanly while the rust-native backends carry the workload.
//!
//! On a host with the real bindings, add to the workspace root:
//! ```toml
//! [patch."<this path>"]  # or just repoint the `xla` path dependency
//! xla = { path = "/opt/xla-rs" }
//! ```

use std::fmt;

/// Error type matching the real bindings' `{e:?}` formatting usage.
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT unavailable (offline `xla` stub build; the native trainer/compression \
         backends are the supported path here)"
    ))
}

/// Element payload of a [`Literal`]. Only the dtypes the workspace moves
/// across the PJRT boundary are represented.
#[derive(Clone, Debug, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types storable in a stub [`Literal`].
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Payload {
        Payload::F32(v)
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Payload {
        Payload::I32(v)
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

/// Host-side tensor: data + dims. Fully functional in the stub.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { payload: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Scalar f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { payload: Payload::F32(vec![x]), dims: vec![] }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload).ok_or_else(|| XlaError("to_vec: dtype mismatch".into()))
    }

    /// First element as `T` (scalar extraction).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| XlaError("get_first_element: empty literal".into()))
    }

    /// Decompose a tuple literal. Stub literals are never tuples (they can
    /// only come back from `exec`, which is unavailable), so this errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text. Construction requires the native parser.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's single gate:
/// it always errors, so no downstream PJRT call is ever reachable.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        assert_eq!(Literal::scalar(7.5).get_first_element::<f32>().unwrap(), 7.5);
    }

    #[test]
    fn pjrt_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("stub"));
    }
}

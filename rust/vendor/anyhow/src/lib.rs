//! Vendored offline subset of the `anyhow` API (the build environment has
//! no network access to crates.io). Implements the slice this workspace
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` (that keeps the blanket `From<E: Error>` impl
//! coherent) and the alternate form `{:#}` renders the full context chain
//! (`outermost: ...: root cause`).

use std::fmt;

/// A string-chained error value. Each `.context(...)` layer wraps the
/// previous error as its `source`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }

    /// The root cause's message (innermost layer).
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(src);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std error's own source chain as context layers.
        let mut layers = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(s) = cur {
            layers.push(s.to_string());
            cur = s.source();
        }
        let mut err = Error::msg(layers.pop().unwrap());
        while let Some(m) = layers.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $msg))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        let e = anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(g().is_err());
    }
}

//! Durable rounds: the journal subsystem's headline invariants.
//!
//! 1. **Resume bit-identity.** A run killed at ANY append point and then
//!    resumed from its journal finishes with the same final model bits,
//!    traffic ledger, per-round records — and the same journal file,
//!    byte for byte — as the run that was never interrupted.
//! 2. **Torn tails are discarded, never trusted.** Truncation at every
//!    byte offset and single-bit flips anywhere in the image always
//!    recover a valid record prefix without panicking.
//! 3. **Offline replay.** `journal::verify` re-derives the run from the
//!    records alone — no trainer, no fleet — and catches digest, traffic
//!    and bookkeeping corruption.
//! 4. **Journaling is an observer.** Writing the journal must not
//!    perturb the run, and the networked coordinator journals the exact
//!    bytes the in-process one does.

use std::path::{Path, PathBuf};
use std::time::Duration;

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::{RoundRecord, RunResult, Server};
use caesar_fl::fleet::FleetKind;
use caesar_fl::journal::{
    self, Dropout, EndRound, KillSink, ParamBlock, PlanEntry, Record, RoundClose, RoundOpen,
    RunHeader, Snapshot, JOURNAL_VERSION,
};
use caesar_fl::schemes::{self, DownloadCodec, UploadCodec};
use caesar_fl::transport::{
    model_digest, CoordinatorService, DeviceClient, LoopbackHub, SessionEnd,
};
use caesar_fl::util::prop::{forall, Config as PropConfig};
use caesar_fl::util::rng::{Rng, RngState};

const N_DEVICES: usize = 6;
const SNAP_EVERY: usize = 2;

fn tiny_cfg(rounds: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    cfg.fleet = FleetKind::JetsonScaled(N_DEVICES);
    cfg.rounds = rounds;
    cfg.alpha = 0.5; // 3 participants per round
    cfg.n_train = 240;
    cfg.n_test = 120;
    cfg.tau = 2;
    cfg.batch = 8;
    cfg.eval_every = 2;
    cfg.seed = 7;
    cfg.engine.workers = workers;
    cfg
}

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("caesar_durability_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let _ = std::fs::remove_file(&path);
    path
}

/// One journaled run against `path`; `kill` arms the fault injector to
/// tear the `kill`-th append (0-based) mid-frame and die.
fn journaled_run(
    cfg: &ExperimentConfig,
    scheme: &str,
    path: &Path,
    kill: Option<usize>,
) -> anyhow::Result<(Server, RunResult)> {
    let (mut srv, mut jw) =
        Server::journaled_open(cfg.clone(), schemes::by_name(scheme).unwrap(), path, SNAP_EVERY)?;
    if let Some(k) = kill {
        jw.map_sink(|s| Box::new(KillSink::new(s, k, 3)));
    }
    let result = srv.run_journaled(&mut jw)?;
    Ok((srv, result))
}

/// Bit-exact comparison of everything the durability invariant covers.
fn assert_identical(what: &str, a: (&Server, &RunResult), b: (&Server, &RunResult)) {
    let ((sa, ra), (sb, rb)) = (a, b);
    assert_eq!(model_digest(&sa.global), model_digest(&sb.global), "{what}: final model");
    assert_eq!(
        sa.traffic().down_bits.to_bits(),
        sb.traffic().down_bits.to_bits(),
        "{what}: download traffic"
    );
    assert_eq!(
        sa.traffic().up_bits.to_bits(),
        sb.traffic().up_bits.to_bits(),
        "{what}: upload traffic"
    );
    assert_eq!(sa.sim_time_s().to_bits(), sb.sim_time_s().to_bits(), "{what}: clock");
    assert_eq!(sa.model_version(), sb.model_version(), "{what}: model version");
    assert_eq!(ra.records.len(), rb.records.len(), "{what}: record count");
    for (x, y) in ra.records.iter().zip(&rb.records) {
        assert_eq!(x.t, y.t, "{what}: round ids");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{what}: round {}", x.t);
        assert_eq!(x.traffic_gb.to_bits(), y.traffic_gb.to_bits(), "{what}: round {}", x.t);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{what}: round {}", x.t);
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{what}: round {}", x.t);
    }
}

// ---------------------------------------------------------------------
// journaling is a pure observer
// ---------------------------------------------------------------------

#[test]
fn journaling_does_not_perturb_the_run_and_replay_verifies_it() {
    let cfg = tiny_cfg(4, 1);
    let mut plain_srv = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap()).unwrap();
    let plain = plain_srv.run().unwrap();

    let path = tmp_path("observer.cjl");
    let (srv, result) = journaled_run(&cfg, "caesar", &path, None).unwrap();
    assert_identical("journaled vs plain", (&srv, &result), (&plain_srv, &plain));

    // the finished journal replays offline — no trainer — and every
    // recorded digest cross-checks
    let (rec, bytes) = journal::recover_file(&path).unwrap();
    assert_eq!(rec.discarded(bytes.len()), 0, "a clean run leaves no torn tail");
    let summary = journal::verify(&rec.records).unwrap();
    assert_eq!(summary.rounds, cfg.rounds);
    assert!(!summary.partial_tail, "run closed with its final snapshot");
    assert_eq!(summary.final_model_digest, model_digest(&srv.global));
    assert_eq!(summary.down_bits.to_bits(), srv.traffic().down_bits.to_bits());
    assert_eq!(summary.up_bits.to_bits(), srv.traffic().up_bits.to_bits());
    assert_eq!(summary.sim_time_s.to_bits(), srv.sim_time_s().to_bits());
}

// ---------------------------------------------------------------------
// kill-point sweep: resume is bit-identical
// ---------------------------------------------------------------------

#[test]
fn every_kill_point_resumes_bit_identically() {
    let cfg = tiny_cfg(4, 1);
    let golden_path = tmp_path("golden.cjl");
    let (gold_srv, gold_res) = journaled_run(&cfg, "caesar", &golden_path, None).unwrap();
    let golden = std::fs::read(&golden_path).unwrap();
    let (gold_rec, _) = journal::recover_file(&golden_path).unwrap();
    let n_appends = gold_rec.records.len();
    assert!(n_appends > 2 * cfg.rounds, "sweep would be vacuous: {n_appends} appends");

    let path = tmp_path("killsweep.cjl");
    for k in 0..n_appends {
        let _ = std::fs::remove_file(&path);
        let err = journaled_run(&cfg, "caesar", &path, Some(k))
            .err()
            .unwrap_or_else(|| panic!("kill at append {k} did not fire"));
        assert!(
            err.to_string().contains("kill point"),
            "kill at {k}: unexpected error {err:#}"
        );
        // the dead process left k whole records plus a torn fragment;
        // a fresh open resumes and finishes the run
        let (srv, result) = journaled_run(&cfg, "caesar", &path, None)
            .unwrap_or_else(|e| panic!("resume after kill at {k} failed: {e:#}"));
        assert_identical(
            &format!("kill at {k}"),
            (&srv, &result),
            (&gold_srv, &gold_res),
        );
        let resumed = std::fs::read(&path).unwrap();
        assert_eq!(resumed, golden, "kill at {k}: journal file diverged from uninterrupted run");
    }
}

#[test]
fn kill_points_resume_for_other_schemes_worker_counts_and_dropouts() {
    for (scheme, workers, dropout) in
        [("prowd", 1, 0.0), ("fedavg", 4, 0.0), ("caesar", 4, 0.4)]
    {
        let mut cfg = tiny_cfg(4, workers);
        cfg.engine.dropout_rate = dropout;
        let what = format!("{scheme}/w{workers}/d{dropout}");
        let golden_path = tmp_path(&format!("golden_{scheme}_{workers}.cjl"));
        let (gold_srv, gold_res) = journaled_run(&cfg, scheme, &golden_path, None).unwrap();
        let golden = std::fs::read(&golden_path).unwrap();
        let (gold_rec, _) = journal::recover_file(&golden_path).unwrap();
        let n_appends = gold_rec.records.len();

        let path = tmp_path(&format!("killsweep_{scheme}_{workers}.cjl"));
        // semantic kill points: mid-preamble, mid-round-1, mid-run, and
        // the very last append
        for k in [1, 4, n_appends / 2, n_appends - 1] {
            let _ = std::fs::remove_file(&path);
            journaled_run(&cfg, scheme, &path, Some(k))
                .err()
                .unwrap_or_else(|| panic!("{what}: kill at {k} did not fire"));
            let (srv, result) = journaled_run(&cfg, scheme, &path, None)
                .unwrap_or_else(|e| panic!("{what}: resume after kill at {k} failed: {e:#}"));
            assert_identical(&format!("{what} kill {k}"), (&srv, &result), (&gold_srv, &gold_res));
            assert_eq!(
                std::fs::read(&path).unwrap(),
                golden,
                "{what}: journal diverged after kill at {k}"
            );
        }
    }
}

#[test]
fn reopening_a_finished_journal_reproduces_the_result_without_retraining() {
    let cfg = tiny_cfg(4, 1);
    let path = tmp_path("finished.cjl");
    let (srv, result) = journaled_run(&cfg, "caesar", &path, None).unwrap();
    let before = std::fs::read(&path).unwrap();
    // rounds=4 with SNAP_EVERY=2 ends on a snapshot, so everything is
    // restorable state: no rounds re-execute
    let (srv2, result2) = journaled_run(&cfg, "caesar", &path, None).unwrap();
    assert_identical("reopen", (&srv2, &result2), (&srv, &result));
    assert_eq!(std::fs::read(&path).unwrap(), before, "reopen must not rewrite the journal");
}

#[test]
fn a_journal_from_a_different_scheme_or_config_is_refused() {
    let cfg = tiny_cfg(2, 1);
    let path = tmp_path("identity.cjl");
    journaled_run(&cfg, "caesar", &path, None).unwrap();

    let err =
        journaled_run(&cfg, "prowd", &path, None).err().expect("scheme mismatch must refuse");
    assert!(err.to_string().contains("scheme"), "{err:#}");

    let mut other = cfg.clone();
    other.seed = 8;
    let err =
        journaled_run(&other, "caesar", &path, None).err().expect("config mismatch must refuse");
    assert!(err.to_string().contains("config"), "{err:#}");
}

#[test]
fn an_unreadable_journal_is_refused_not_clobbered() {
    let cfg = tiny_cfg(2, 1);

    // a non-empty file that is not a journal at all (BadLength at record 0)
    let path = tmp_path("foreign.cjl");
    std::fs::write(&path, [0xFFu8; 64]).unwrap();
    let err =
        journaled_run(&cfg, "caesar", &path, None).err().expect("foreign file must refuse");
    assert!(err.to_string().contains("refusing to overwrite"), "{err:#}");
    assert_eq!(std::fs::read(&path).unwrap(), [0xFFu8; 64], "refusal must not touch the file");

    // a real journal whose header frame took a bit flip (BadCrc at record 0)
    let path = tmp_path("flipped_header.cjl");
    journaled_run(&cfg, "caesar", &path, None).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[6] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err =
        journaled_run(&cfg, "caesar", &path, None).err().expect("corrupt header must refuse");
    assert!(err.to_string().contains("refusing to overwrite"), "{err:#}");
    assert_eq!(std::fs::read(&path).unwrap(), bytes);

    // a journal from a newer format version (Version at record 0): bump
    // the version field and re-seal the CRC so only version skew objects
    let path = tmp_path("newer_version.cjl");
    let mut hdr_cfg = cfg.clone();
    hdr_cfg.trainer = TrainerBackend::Native;
    let mut framed = journal::encode_record(&Record::RunHeader(RunHeader {
        version: JOURNAL_VERSION,
        scheme: "caesar".to_string(),
        snapshot_every: SNAP_EVERY,
        cfg: hdr_cfg,
    }));
    framed[5] = JOURNAL_VERSION as u8 + 1;
    let n = framed.len();
    let crc = journal::crc32(&framed[..n - 4]);
    framed[n - 4..].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(&path, &framed).unwrap();
    let err =
        journaled_run(&cfg, "caesar", &path, None).err().expect("version skew must refuse");
    assert!(err.to_string().contains("journal format version"), "{err:#}");
    assert_eq!(std::fs::read(&path).unwrap(), framed);
}

// ---------------------------------------------------------------------
// torn-tail fuzz over a synthetic journal image
// ---------------------------------------------------------------------

/// A small, fully synthetic 5-round journal image: real record encodings
/// (tiny 4-param models, 3 devices) that keep the truncate-at-every-byte
/// sweep quadratic-affordable. Recovery is structural — the contents
/// need not pass `verify`.
fn synthetic_journal(rounds: usize) -> Vec<u8> {
    let mut rng = Rng::new(0xD15C);
    let n_dev = 3usize;
    let n_params = 4usize;
    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.fleet = FleetKind::JetsonScaled(n_dev);
    let mut recs = vec![Record::RunHeader(RunHeader {
        version: JOURNAL_VERSION,
        scheme: "caesar".to_string(),
        snapshot_every: 2,
        cfg,
    })];
    let snap = |rng: &mut Rng, t: usize| {
        Record::Snapshot(Box::new(Snapshot {
            t,
            model_version: t as u64,
            sim_time_s: t as f64 * 3.5,
            rng: RngState { s: [rng.next_u64(); 4], spare_normal: None },
            down_bits: rng.f64() * 1e9,
            up_bits: rng.f64() * 1e9,
            model: ParamBlock::new((0..n_params).map(|i| i as f32).collect()),
            locals: (0..n_dev)
                .map(|d| {
                    (d % 2 == 0).then(|| {
                        ParamBlock::new((0..n_params).map(|i| (d + i) as f32).collect())
                    })
                })
                .collect(),
            grad_norms: (0..n_dev).map(|d| d as f64).collect(),
            last_round: (0..n_dev).map(|d| d % (t + 1)).collect(),
        }))
    };
    recs.push(snap(&mut rng, 0));
    for t in 1..=rounds {
        recs.push(Record::RoundOpen(RoundOpen {
            t,
            model_version: t as u64 - 1,
            sim_now_s: t as f64,
            lr: 0.1,
            stream_base: 0xBEEF,
            plans: (0..2)
                .map(|d| PlanEntry {
                    device: d,
                    download: DownloadCodec::CaesarSplit { ratio: 0.4 },
                    upload: UploadCodec::TopK { ratio: 0.5 },
                    batch: 16,
                    tau: 5,
                    beta_d: 1e6,
                    beta_u: 5e5,
                    mu: 1e-4,
                })
                .collect(),
        }));
        recs.push(Record::EndRound(EndRound {
            t,
            fold_t: t,
            device: 0,
            w_digest: rng.next_u64(),
            upload_bits: 1024,
            down_wire_bits: 2048,
            grad_norm: 1.5,
            loss: 0.7,
            download_s: 0.1,
            compute_s: 0.2,
            upload_s: 0.3,
        }));
        recs.push(Record::Dropout(Dropout { t, device: 1, after_s: 0.15, down_wire_bits: 2048 }));
        recs.push(Record::RoundClose(RoundClose {
            t,
            completers: 1,
            model_version: t as u64,
            model_digest: rng.next_u64(),
            down_bits: t as f64 * 4096.0,
            up_bits: t as f64 * 1024.0,
            rec: RoundRecord {
                t,
                sim_time_s: t as f64,
                traffic_gb: t as f64 * 1e-3,
                accuracy: if t % 2 == 0 { 0.5 } else { f64::NAN },
                auc: f64::NAN,
                mean_loss: 0.7,
                round_s: 0.6,
                avg_wait_s: 0.0,
                participants: 2,
            },
        }));
        if t % 2 == 0 {
            recs.push(snap(&mut rng, t));
        }
    }
    recs.iter().flat_map(journal::encode_record).collect()
}

#[test]
fn truncation_at_every_byte_recovers_exactly_the_whole_record_prefix() {
    let bytes = synthetic_journal(5);
    let full = journal::recover(&bytes);
    assert_eq!(full.valid_len, bytes.len(), "the synthetic image itself must be valid");
    let ends = full.ends.clone();

    for cut in 0..=bytes.len() {
        let rec = journal::recover(&bytes[..cut]);
        let expect = ends.iter().filter(|&&e| e <= cut).count();
        assert_eq!(rec.records.len(), expect, "cut at {cut}");
        assert_eq!(rec.valid_len, if expect == 0 { 0 } else { ends[expect - 1] }, "cut at {cut}");
        // the newest surviving record decoded to exactly its original
        // frame (earlier ones are covered by smaller cuts)
        if let Some(last) = rec.records.last() {
            let (s, e) = (if expect == 1 { 0 } else { ends[expect - 2] }, ends[expect - 1]);
            assert_eq!(journal::encode_record(last), &bytes[s..e], "cut at {cut}");
        }
    }
}

#[test]
fn bit_flips_never_panic_and_records_before_the_flip_survive() {
    let bytes = synthetic_journal(5);
    let ends = journal::recover(&bytes).ends;
    forall(
        PropConfig { cases: 48, seed: 0xF11B },
        |rng, _size| (rng.below(bytes.len()), rng.below(8)),
        |&(idx, bit)| {
            let mut flipped = bytes.clone();
            flipped[idx] ^= 1 << bit;
            let rec = journal::recover(&flipped);
            // the record containing the flip (and everything after it)
            // may be lost — but never the ones wholly before it
            let before = ends.iter().filter(|&&e| e <= idx).count();
            if rec.records.len() < before {
                return Err(format!(
                    "flip at byte {idx} bit {bit} lost {} intact records",
                    before - rec.records.len()
                ));
            }
            for (j, r) in rec.records.iter().take(before).enumerate() {
                let (s, e) = (if j == 0 { 0 } else { ends[j - 1] }, ends[j]);
                if journal::encode_record(r) != bytes[s..e] {
                    return Err(format!("flip at byte {idx} corrupted earlier record {j}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// offline replay catches corruption
// ---------------------------------------------------------------------

#[test]
fn replay_catches_digest_traffic_and_bookkeeping_corruption() {
    let cfg = tiny_cfg(4, 1);
    let path = tmp_path("replay.cjl");
    journaled_run(&cfg, "caesar", &path, None).unwrap();
    let (rec, _) = journal::recover_file(&path).unwrap();
    journal::verify(&rec.records).expect("the untampered journal verifies");

    // traffic ledger: a close's totals must equal the summed resolutions
    let mut tampered = rec.records.clone();
    let i = tampered
        .iter()
        .rposition(|r| matches!(r, Record::RoundClose(_)))
        .unwrap();
    if let Record::RoundClose(c) = &mut tampered[i] {
        c.down_bits += 1.0;
    }
    journal::verify(&tampered).expect_err("corrupted traffic total must fail replay");

    // per-device upload bits feed the same cross-check from the other side
    let mut tampered = rec.records.clone();
    let i = tampered.iter().position(|r| matches!(r, Record::EndRound(_))).unwrap();
    if let Record::EndRound(e) = &mut tampered[i] {
        e.upload_bits += 1;
    }
    journal::verify(&tampered).expect_err("corrupted upload bits must fail replay");

    // snapshot payloads carry their own digests
    let mut tampered = rec.records.clone();
    let i = tampered.iter().rposition(|r| matches!(r, Record::Snapshot(_))).unwrap();
    if let Record::Snapshot(s) = &mut tampered[i] {
        s.model.w[0] = s.model.w[0] + 1.0;
    }
    journal::verify(&tampered).expect_err("corrupted snapshot model must fail replay");

    // the model-version counter only moves when someone completed
    let mut tampered = rec.records.clone();
    let i = tampered.iter().position(|r| matches!(r, Record::RoundClose(_))).unwrap();
    if let Record::RoundClose(c) = &mut tampered[i] {
        c.model_version += 1;
    }
    journal::verify(&tampered).expect_err("corrupted model version must fail replay");

    // a CRC-valid but nonsensical header config is a typed error, not a
    // panic (eval_every feeds a remainder in the replay loop)
    let mut tampered = rec.records.clone();
    if let Record::RunHeader(h) = &mut tampered[0] {
        h.cfg.eval_every = 0;
    }
    let err = journal::verify(&tampered).expect_err("eval_every=0 must fail replay");
    assert!(err.to_string().contains("eval_every"), "{err:#}");
}

// ---------------------------------------------------------------------
// the networked coordinator journals the same bytes
// ---------------------------------------------------------------------

/// One journaled loopback-networked run against `path` with all
/// `N_DEVICES` device threads attached.
fn loopback_journaled_run(
    cfg: &ExperimentConfig,
    scheme: &str,
    path: &Path,
) -> (Server, RunResult) {
    let (server, mut jw) =
        Server::journaled_open(cfg.clone(), schemes::by_name(scheme).unwrap(), path, SNAP_EVERY)
            .unwrap();
    let hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let mut svc = CoordinatorService::new(server, hub);
    let mut handles = Vec::new();
    for d in 0..N_DEVICES {
        let dialer = dialer.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            let mut conn = dialer.connect().unwrap();
            client.run(&mut conn).unwrap()
        }));
    }
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30)).unwrap();
    let result = svc.run_journaled_cb(&mut jw, |_| {}).unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    (svc.into_server(), result)
}

#[test]
fn networked_journal_matches_the_in_process_journal_byte_for_byte() {
    let cfg = tiny_cfg(3, 1);
    let inproc_path = tmp_path("inproc.cjl");
    let (inproc_srv, inproc_res) = journaled_run(&cfg, "caesar", &inproc_path, None).unwrap();

    let net_path = tmp_path("loopback.cjl");
    let (srv, result) = loopback_journaled_run(&cfg, "caesar", &net_path);
    assert_identical("networked journaled", (&srv, &result), (&inproc_srv, &inproc_res));
    assert_eq!(
        std::fs::read(&net_path).unwrap(),
        std::fs::read(&inproc_path).unwrap(),
        "loopback and in-process journals must be byte-identical"
    );
}

// ---------------------------------------------------------------------
// semi-async pipelined rounds stay durable
// ---------------------------------------------------------------------

/// `tiny_cfg` with the semi-async window open: two rounds in flight and
/// a staleness buffer holding one round of lag.
fn pipelined_cfg(rounds: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = tiny_cfg(rounds, workers);
    cfg.engine.pipeline_depth = 2;
    cfg.engine.staleness_bound = 1;
    cfg
}

#[test]
fn pipelined_journals_replay_offline_and_every_kill_point_resumes_bit_identically() {
    let cfg = pipelined_cfg(4, 1);
    let golden_path = tmp_path("pipe_golden.cjl");
    let (gold_srv, gold_res) = journaled_run(&cfg, "caesar", &golden_path, None).unwrap();
    let golden = std::fs::read(&golden_path).unwrap();
    let (gold_rec, _) = journal::recover_file(&golden_path).unwrap();

    // offline replay re-derives the fold schedule (the cost-median
    // lateness rule) from the records alone — no trainer — and
    // cross-checks every digest, traffic total and model-version bump
    let summary = journal::verify(&gold_rec.records).unwrap();
    assert_eq!(summary.rounds, cfg.rounds);
    assert!(!summary.partial_tail, "run closed with its final snapshot");
    assert_eq!(summary.final_model_digest, model_digest(&gold_srv.global));
    assert_eq!(summary.down_bits.to_bits(), gold_srv.traffic().down_bits.to_bits());
    assert_eq!(summary.up_bits.to_bits(), gold_srv.traffic().up_bits.to_bits());
    assert_eq!(summary.sim_time_s.to_bits(), gold_srv.sim_time_s().to_bits());

    // kill-at-every-append: the open window and staleness buffer are
    // provably drained at snapshot boundaries, so resume needs no new
    // record kinds — and must stay byte-identical
    let n_appends = gold_rec.records.len();
    assert!(n_appends > 2 * cfg.rounds, "sweep would be vacuous: {n_appends} appends");
    let path = tmp_path("pipe_killsweep.cjl");
    for k in 0..n_appends {
        let _ = std::fs::remove_file(&path);
        let err = journaled_run(&cfg, "caesar", &path, Some(k))
            .err()
            .unwrap_or_else(|| panic!("pipelined kill at append {k} did not fire"));
        assert!(
            err.to_string().contains("kill point"),
            "pipelined kill at {k}: unexpected error {err:#}"
        );
        let (srv, result) = journaled_run(&cfg, "caesar", &path, None)
            .unwrap_or_else(|e| panic!("pipelined resume after kill at {k} failed: {e:#}"));
        assert_identical(
            &format!("pipelined kill at {k}"),
            (&srv, &result),
            (&gold_srv, &gold_res),
        );
        assert_eq!(
            std::fs::read(&path).unwrap(),
            golden,
            "pipelined kill at {k}: journal diverged from uninterrupted run"
        );
    }
}

#[test]
fn a_pipelined_journal_refuses_the_barrier_config_and_vice_versa() {
    // pipeline knobs are part of the journal's config identity: resuming
    // a depth-2 journal with barrier knobs (or the reverse) must refuse
    // rather than silently produce a different run
    let pipe = pipelined_cfg(2, 1);
    let path = tmp_path("pipe_identity.cjl");
    journaled_run(&pipe, "caesar", &path, None).unwrap();
    let barrier = tiny_cfg(2, 1);
    let err = journaled_run(&barrier, "caesar", &path, None)
        .err()
        .expect("depth mismatch must refuse");
    assert!(err.to_string().contains("config"), "{err:#}");
}

#[test]
fn networked_pipelined_journal_matches_the_in_process_one_byte_for_byte() {
    let cfg = pipelined_cfg(3, 1);
    let inproc_path = tmp_path("pipe_inproc.cjl");
    let (inproc_srv, inproc_res) = journaled_run(&cfg, "caesar", &inproc_path, None).unwrap();

    let net_path = tmp_path("pipe_loopback.cjl");
    let (srv, result) = loopback_journaled_run(&cfg, "caesar", &net_path);
    assert_identical(
        "networked pipelined journaled",
        (&srv, &result),
        (&inproc_srv, &inproc_res),
    );
    assert_eq!(
        std::fs::read(&net_path).unwrap(),
        std::fs::read(&inproc_path).unwrap(),
        "pipelined loopback and in-process journals must be byte-identical"
    );
}

//! Fast versions of every paper experiment runner — proves each figure's
//! driver executes end to end and writes its artifacts. Uses the native
//! trainer with shrunken workloads; full-fidelity runs are `caesar all`.

use caesar_fl::experiments;
use caesar_fl::util::cli::Args;

fn args(tmp: &std::path::Path, extra: &str) -> Args {
    Args::parse(
        format!(
            "x out={} rounds=3 n-train=700 tau=2 eval-every=1 trainer=native --quiet {extra}",
            tmp.display()
        )
        .split_whitespace()
        .map(String::from),
    )
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("caesar_exp_smoke_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn fig1_prelim_writes_runs_and_summary() {
    let tmp = tmpdir("fig1");
    experiments::run_by_name("fig1", &args(&tmp, "")).unwrap();
    assert!(tmp.join("fig1/fig1b_summary.txt").exists());
    assert!(tmp.join("fig1/nocomp_cifar_prelim.csv").exists());
    assert!(tmp.join("fig1/gm-cac_cifar_prelim.csv").exists());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn fig5_table3_all_tasks_single() {
    let tmp = tmpdir("fig5");
    experiments::run_by_name("fig5", &args(&tmp, "task=har")).unwrap();
    let t3 = std::fs::read_to_string(tmp.join("main/table3.csv")).unwrap();
    assert_eq!(t3.lines().count(), 6); // header + 5 schemes
    assert!(t3.contains("caesar"));
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn fig8_heterogeneity_sweep() {
    let tmp = tmpdir("fig8");
    // pin p via override so the sweep collapses to one level per task
    experiments::run_by_name("fig8", &args(&tmp, "task=har p=5")).unwrap();
    let csv = std::fs::read_to_string(tmp.join("fig8/fig8_acc.csv")).unwrap();
    assert!(csv.lines().count() > 5);
    assert!(tmp.join("fig8/fig8d_degradation.csv").exists());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn fig9_ablation() {
    let tmp = tmpdir("fig9");
    experiments::run_by_name("fig9", &args(&tmp, "task=har")).unwrap();
    assert!(tmp.join("fig9/fig9_ablation.txt").exists());
    assert!(tmp.join("fig9/caesar-br_har_abl.csv").exists());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn fig10_scale() {
    let tmp = tmpdir("fig10");
    experiments::run_by_name("fig10", &args(&tmp, "devices=16")).unwrap();
    assert!(tmp.join("fig10/fig10_scale.txt").exists());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn table3_is_an_alias_for_fig5() {
    let tmp = tmpdir("t3");
    experiments::run_by_name("table3", &args(&tmp, "task=oppo")).unwrap();
    assert!(tmp.join("main/table3.csv").exists());
    let _ = std::fs::remove_dir_all(&tmp);
}

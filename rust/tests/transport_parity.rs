//! The transport subsystem's headline invariant: for a fixed seed, a run
//! driven over a real transport — Loopback channels or Tcp on localhost,
//! with devices arriving in any scripted order, disconnecting and
//! rejoining — produces BIT-IDENTICAL final models, traffic ledgers and
//! round records to the in-process `Server::run` path. The transport
//! moves bytes; it never touches the math.

use std::time::{Duration, Instant};

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::{RunResult, Server};
use caesar_fl::fleet::FleetKind;
use caesar_fl::schemes;
use caesar_fl::transport::frame::reject;
use caesar_fl::transport::{
    model_digest, Conn, CoordinatorService, DeviceClient, DeviceFleet, LoopbackHub, SessionEnd,
    TcpConn, TcpTransport, TransportError, WireMsg,
};

const N_DEVICES: usize = 6;

fn tiny_cfg(rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    cfg.fleet = FleetKind::JetsonScaled(N_DEVICES);
    cfg.rounds = rounds;
    cfg.alpha = 0.5; // 3 participants per round
    cfg.n_train = 600;
    cfg.n_test = 200;
    cfg.tau = 2;
    cfg.batch = 8;
    cfg.eval_every = 1;
    cfg.seed = 7;
    cfg
}

fn baseline(cfg: &ExperimentConfig, scheme: &str) -> (Server, RunResult) {
    let mut srv = Server::new(cfg.clone(), schemes::by_name(scheme).unwrap()).unwrap();
    let result = srv.run().unwrap();
    (srv, result)
}

/// Bit-exact comparison of everything the parity invariant covers.
/// Engine *stats* are deliberately excluded: the networked service runs
/// liveness sweeps and counts frames, not simulated events.
fn assert_parity(what: &str, a: (&Server, &RunResult), b: (&Server, &RunResult)) {
    let ((sa, ra), (sb, rb)) = (a, b);
    assert_eq!(
        model_digest(&sa.global),
        model_digest(&sb.global),
        "{what}: final model diverged"
    );
    for (i, (x, y)) in sa.global.iter().zip(&sb.global).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: model elem {i}");
    }
    assert_eq!(
        sa.traffic().down_bits.to_bits(),
        sb.traffic().down_bits.to_bits(),
        "{what}: download traffic"
    );
    assert_eq!(
        sa.traffic().up_bits.to_bits(),
        sb.traffic().up_bits.to_bits(),
        "{what}: upload traffic"
    );
    assert_eq!(sa.sim_time_s().to_bits(), sb.sim_time_s().to_bits(), "{what}: clock");
    assert_eq!(sa.model_version(), sb.model_version(), "{what}: model version");
    assert_eq!(ra.records.len(), rb.records.len(), "{what}: record count");
    for (x, y) in ra.records.iter().zip(&rb.records) {
        assert_eq!(x.t, y.t, "{what}: round ids");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{what}: round {}", x.t);
        assert_eq!(x.traffic_gb.to_bits(), y.traffic_gb.to_bits(), "{what}: round {}", x.t);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{what}: round {}", x.t);
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{what}: round {}", x.t);
    }
}

/// Run the service over Loopback with device threads arriving in the
/// scripted `arrival` order.
fn run_loopback(cfg: &ExperimentConfig, scheme: &str, arrival: &[usize]) -> (Server, RunResult) {
    let server = Server::new(cfg.clone(), schemes::by_name(scheme).unwrap()).unwrap();
    let hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let mut svc = CoordinatorService::new(server, hub);
    let mut handles = Vec::new();
    for &d in arrival {
        let dialer = dialer.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            let mut conn = dialer.connect().unwrap();
            client.run(&mut conn).unwrap()
        }));
        // stagger so the hub really sees this arrival order
        std::thread::sleep(Duration::from_millis(2));
    }
    svc.wait_for_devices(arrival.len(), Duration::from_secs(30)).unwrap();
    let result = svc.run().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    (svc.into_server(), result)
}

/// Run the service over Tcp on an ephemeral localhost port.
fn run_tcp(cfg: &ExperimentConfig, scheme: &str, arrival: &[usize]) -> (Server, RunResult) {
    let server = Server::new(cfg.clone(), schemes::by_name(scheme).unwrap()).unwrap();
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.socket_addr();
    let mut svc = CoordinatorService::new(server, transport);
    let mut handles = Vec::new();
    for &d in arrival {
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            let mut conn = TcpConn::connect(addr).unwrap();
            client.run(&mut conn).unwrap()
        }));
        std::thread::sleep(Duration::from_millis(2));
    }
    svc.wait_for_devices(arrival.len(), Duration::from_secs(30)).unwrap();
    let result = svc.run().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    (svc.into_server(), result)
}

/// Run the service over Tcp with the devices packed into fleets — each
/// inner slice is one [`DeviceFleet`] multiplexed over ONE connection,
/// dialed in the scripted (outer) order.
fn run_tcp_fleet(
    cfg: &ExperimentConfig,
    scheme: &str,
    fleets: &[Vec<usize>],
) -> (Server, RunResult) {
    let server = Server::new(cfg.clone(), schemes::by_name(scheme).unwrap()).unwrap();
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.socket_addr();
    let mut svc = CoordinatorService::new(server, transport);
    let n: usize = fleets.iter().map(Vec::len).sum();
    let mut handles = Vec::new();
    for members in fleets {
        let cfg = cfg.clone();
        let members = members.clone();
        handles.push(std::thread::spawn(move || {
            let mut fleet = DeviceFleet::new(cfg, members).unwrap();
            let mut conn = TcpConn::connect(addr).unwrap();
            fleet.run(&mut conn).unwrap()
        }));
        std::thread::sleep(Duration::from_millis(2));
    }
    svc.wait_for_devices(n, Duration::from_secs(30)).unwrap();
    let result = svc.run().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    (svc.into_server(), result)
}

#[test]
fn fleet_multiplexed_tcp_matches_every_other_path_bit_for_bit() {
    // the multiplexing invariant across the full matrix the service
    // supports: scheme × pipeline depth × connection packing. How the
    // six devices pack onto sockets (6×1, 2 fleets of 3, 1 fleet of 6)
    // must be invisible to models, traffic, clock and records.
    for scheme in ["caesar", "fedavg"] {
        for depth in [1usize, 2] {
            let mut cfg = tiny_cfg(2);
            if depth == 2 {
                cfg.engine.pipeline_depth = 2;
                cfg.engine.staleness_bound = 2;
            }
            let what = format!("{scheme}/depth{depth}");
            let base = baseline(&cfg, scheme);
            let tcp = run_tcp(&cfg, scheme, &[2, 5, 0, 3, 1, 4]);
            assert_parity(&format!("{what}: 6-conn tcp"), (&tcp.0, &tcp.1), (&base.0, &base.1));
            let packed = run_tcp_fleet(&cfg, scheme, &[vec![3, 4, 5], vec![0, 1, 2]]);
            assert_parity(
                &format!("{what}: 2 fleets x 3 devices"),
                (&packed.0, &packed.1),
                (&base.0, &base.1),
            );
            let single = run_tcp_fleet(&cfg, scheme, &[vec![0, 1, 2, 3, 4, 5]]);
            assert_parity(
                &format!("{what}: 1 fleet x 6 devices"),
                (&single.0, &single.1),
                (&base.0, &base.1),
            );
        }
    }
}

#[test]
fn loopback_and_tcp_match_the_in_process_run_bit_for_bit() {
    let cfg = tiny_cfg(3);
    // caesar exercises the full codec surface: CaesarSplit + Full
    // downloads, TopK uploads, cross-round cache reuse
    let base = baseline(&cfg, "caesar");
    // scripted arrival orders, both far from ascending
    let lb = run_loopback(&cfg, "caesar", &[4, 1, 5, 0, 3, 2]);
    assert_parity("loopback vs in-process", (&lb.0, &lb.1), (&base.0, &base.1));
    let tcp = run_tcp(&cfg, "caesar", &[2, 5, 0, 3, 1, 4]);
    assert_parity("tcp vs in-process", (&tcp.0, &tcp.1), (&base.0, &base.1));
}

#[test]
fn pipelined_depth_two_matches_the_in_process_run_bit_for_bit() {
    // the semi-async tentpole over the wire: with a depth-2 window two
    // rounds are open at once, EndRound/Dropout frames carry their round
    // id and route to the matching window slot, and the staleness fold
    // happens inside the shared `Server` — so a networked pipelined run
    // must reproduce the in-process pipelined run exactly, records and
    // traffic included
    let mut cfg = tiny_cfg(4);
    cfg.engine.pipeline_depth = 2;
    cfg.engine.staleness_bound = 2;
    let base = baseline(&cfg, "caesar");
    let lb = run_loopback(&cfg, "caesar", &[5, 2, 0, 4, 1, 3]);
    assert_parity("pipelined loopback vs in-process", (&lb.0, &lb.1), (&base.0, &base.1));
    let tcp = run_tcp(&cfg, "caesar", &[3, 0, 5, 1, 4, 2]);
    assert_parity("pipelined tcp vs in-process", (&tcp.0, &tcp.1), (&base.0, &base.1));
}

#[test]
fn quant_noise_and_fedavg_survive_the_wire_too() {
    // prowd's Quant download draws device-stream noise — the RNG
    // resume-state handoff in the kickoff frame is what keeps this exact
    for scheme in ["prowd", "fedavg"] {
        let cfg = tiny_cfg(2);
        let base = baseline(&cfg, scheme);
        let lb = run_loopback(&cfg, scheme, &[5, 4, 3, 2, 1, 0]);
        assert_parity(scheme, (&lb.0, &lb.1), (&base.0, &base.1));
    }
}

#[test]
fn dropout_lottery_and_heartbeats_are_identical_across_transports() {
    let mut cfg = tiny_cfg(3);
    cfg.engine.dropout_rate = 0.4;
    cfg.engine.heartbeat_s = 5.0;
    let base = baseline(&cfg, "caesar");
    let lb = run_loopback(&cfg, "caesar", &[3, 0, 5, 2, 4, 1]);
    assert_parity("dropout loopback", (&lb.0, &lb.1), (&base.0, &base.1));
    let tcp = run_tcp(&cfg, "caesar", &[1, 3, 5, 0, 2, 4]);
    assert_parity("dropout tcp", (&tcp.0, &tcp.1), (&base.0, &base.1));
}

#[test]
fn idle_unselected_devices_are_never_marked_dropped() {
    // heartbeats well shorter than a round's simulated duration: only
    // kickoff-executing devices ever heartbeat, so a blanket liveness
    // sweep between rounds would evict every healthy unselected device
    // and inflate the dropout diagnostics (the bug this test pins)
    let mut cfg = tiny_cfg(3);
    cfg.engine.heartbeat_s = 0.5;
    let (srv, _result) = run_loopback(&cfg, "caesar", &[0, 1, 2, 3, 4, 5]);
    assert_eq!(srv.engine().stats().dropouts, 0, "no device dropped out");
    // the registry only hears from selected participants, so a device the
    // lottery never picked legitimately stays Offline — but nobody may
    // end the run Training or Dropped
    let (offline, idle, training, dropped) = srv.engine().registry().census();
    assert_eq!((training, dropped), (0, 0), "healthy devices must not end Dropped");
    assert_eq!(offline + idle, N_DEVICES);
}

/// A [`Conn`] whose receive side stays silent until a wall-clock gate
/// passes — the deterministic stand-in for a device whose kickoff sits
/// in a delivery queue past the round deadline.
struct GatedConn {
    inner: caesar_fl::transport::LoopbackConn,
    gate: Instant,
}

impl Conn for GatedConn {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        self.inner.send(msg)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        if Instant::now() < self.gate {
            std::thread::sleep(timeout.min(Duration::from_millis(10)));
            return Ok(None);
        }
        self.inner.recv_timeout(timeout)
    }
    fn peer(&self) -> String {
        self.inner.peer()
    }
}

/// The high-severity stale-round scenario: a straggler sleeps through
/// round 1's deadline (the coordinator converts it to a synthesized
/// Dropout), then wakes and executes BOTH buffered kickoffs. Its late
/// round-1 EndRound must be refused as stale — not folded into round 2 —
/// and its round-2 EndRound must be accepted, with the prior-digest
/// handshake resyncing the recovery prior (the coordinator holds no
/// local for it; the client retains one from its late round-1 run).
#[test]
fn a_straggler_past_the_deadline_is_refused_stale_and_recovers_next_round() {
    let mut cfg = tiny_cfg(2);
    cfg.alpha = 1.0; // every device participates in both rounds
    let server = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap()).unwrap();
    let hub = LoopbackHub::new();
    let dialer = hub.dialer();
    let mut svc = CoordinatorService::new(server, hub);
    svc.round_timeout = Duration::from_secs(2);
    let gate = Instant::now() + Duration::from_secs(3);
    let mut handles = Vec::new();
    for d in 0..N_DEVICES {
        let dialer = dialer.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            let end = if d == 3 {
                let mut conn = GatedConn { inner: dialer.connect().unwrap(), gate };
                client.run(&mut conn).unwrap()
            } else {
                let mut conn = dialer.connect().unwrap();
                client.run(&mut conn).unwrap()
            };
            (d, end, client.stats)
        }));
    }
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30)).unwrap();
    let result = svc.run().unwrap();
    assert_eq!(result.records.len(), 2);
    for h in handles {
        let (d, end, stats) = h.join().unwrap();
        assert_eq!(end, SessionEnd::Finished, "device {d}");
        if d == 3 {
            // it executed both kickoffs late; exactly the round-1
            // resolution was refused as stale
            assert_eq!(stats.rounds, 2, "straggler executed both rounds");
            assert_eq!(stats.stale_rejects, 1, "late round-1 EndRound refused");
        } else {
            assert_eq!(stats.rounds, 2, "device {d}");
            assert_eq!(stats.stale_rejects, 0, "device {d}");
        }
    }
    let srv = svc.into_server();
    // round 1 dropped the straggler (once) and round 2 accepted it
    assert_eq!(srv.engine().stats().dropouts, 1);
    assert_eq!(srv.engine().registry().dropouts(3), 1);
    assert_eq!(srv.engine().registry().completions(3), 1);
}

/// A [`Conn`] that kills itself after a budgeted number of sends — the
/// deterministic stand-in for a mid-round connection loss.
struct FlakyConn {
    inner: TcpConn,
    sends_left: usize,
}

impl Conn for FlakyConn {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        if self.sends_left == 0 {
            return Err(TransportError::Closed);
        }
        self.sends_left -= 1;
        self.inner.send(msg)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        if self.sends_left == 0 {
            return Err(TransportError::Closed);
        }
        self.inner.recv_timeout(timeout)
    }
    fn peer(&self) -> String {
        self.inner.peer()
    }
}

#[test]
fn a_device_that_dies_mid_session_rejoins_and_parity_holds() {
    let cfg = tiny_cfg(3);
    let base = baseline(&cfg, "caesar");

    let server = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap()).unwrap();
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.socket_addr();
    let mut svc = CoordinatorService::new(server, transport);
    let mut handles = Vec::new();
    for d in 0..N_DEVICES {
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            if d == 2 {
                // device 2's first connection dies after 2 frames (Join +
                // one more), forcing a reconnect-with-rejoin; later dials
                // get an unlimited budget
                let mut dials = 0usize;
                client
                    .run_reconnecting(
                        move || {
                            dials += 1;
                            Ok(FlakyConn {
                                inner: TcpConn::connect(addr)?,
                                sends_left: if dials == 1 { 2 } else { usize::MAX },
                            })
                        },
                        10,
                    )
                    .unwrap()
            } else {
                let mut conn = TcpConn::connect(addr).unwrap();
                client.run(&mut conn).unwrap()
            }
        }));
    }
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30)).unwrap();
    let result = svc.run().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    let srv = svc.into_server();
    assert_parity("flaky device", (&srv, &result), (&base.0, &base.1));
}

/// Mid-round fleet-connection death: one socket carrying THREE device
/// sessions dies after its Join storm plus one resolution, so the
/// coordinator severs all three bindings at once
/// (`Registry::unbind_conn`) while keeping the devices pending. The
/// fleet redials as a unit, re-Joins every member, and the coordinator
/// redelivers the pending kickoffs — unresolved rounds are re-served
/// (bit-identically: the local models never advanced) and anything
/// already resolved is answered from the redelivery cache, never
/// retrained. Parity with the in-process run must survive all of it.
#[test]
fn a_fleet_connection_that_dies_mid_round_rejoins_and_parity_holds() {
    let cfg = tiny_cfg(3);
    let base = baseline(&cfg, "caesar");

    let server = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap()).unwrap();
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.socket_addr();
    let mut svc = CoordinatorService::new(server, transport);

    // devices 0..2 ride one flaky fleet connection; 3..5 ride a healthy
    // single-device connection each
    let cfg_fleet = cfg.clone();
    let flaky = std::thread::spawn(move || {
        let mut fleet = DeviceFleet::new(cfg_fleet, [0, 1, 2]).unwrap();
        let mut dials = 0usize;
        let end = fleet
            .run_reconnecting(
                move || {
                    dials += 1;
                    Ok(FlakyConn {
                        inner: TcpConn::connect(addr)?,
                        // first dial: the 3-frame Join storm plus ONE
                        // resolution, then the socket dies mid-round;
                        // later dials get an unlimited budget
                        sends_left: if dials == 1 { 4 } else { usize::MAX },
                    })
                },
                10,
            )
            .unwrap();
        (end, fleet.stats())
    });
    let mut singles = Vec::new();
    for d in 3..N_DEVICES {
        let cfg = cfg.clone();
        singles.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            let mut conn = TcpConn::connect(addr).unwrap();
            client.run(&mut conn).unwrap()
        }));
    }
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30)).unwrap();
    let result = svc.run().unwrap();
    let (end, stats) = flaky.join().unwrap();
    assert_eq!(end, SessionEnd::Finished, "the fleet must finish after its rejoin");
    assert!(stats.rounds >= 1, "the fleet served rounds across the death");
    for h in singles {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    let srv = svc.into_server();
    assert_parity("flaky fleet connection", (&srv, &result), (&base.0, &base.1));
}

/// A poisoned fleet connection: a peer identifies TWO devices, then
/// sends framing garbage mid-round. The coordinator must synthesize
/// Dropouts for BOTH multiplexed devices immediately — one socket is
/// one failure domain — and close the round well before the wall-clock
/// deadline (a poisoned peer is cut, not waited out).
#[test]
fn a_poisoned_fleet_connection_drops_all_its_devices_immediately() {
    use std::io::Write;

    let mut cfg = tiny_cfg(1);
    cfg.alpha = 1.0; // all six devices participate in the round
    let server = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap()).unwrap();
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.socket_addr();
    let mut svc = CoordinatorService::new(server, transport);
    svc.round_timeout = Duration::from_secs(60);

    // the hostile fleet: Join frames for devices 4 and 5 over one raw
    // socket, then garbage bytes once the round is underway
    let hostile = std::thread::spawn(move || {
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        sock.write_all(&caesar_fl::transport::encode_frame(&WireMsg::Join { device: 4 }))
            .unwrap();
        sock.write_all(&caesar_fl::transport::encode_frame(&WireMsg::Join { device: 5 }))
            .unwrap();
        std::thread::sleep(Duration::from_millis(300));
        // not a frame: wrong magic, decodes to FrameError on arrival
        let _ = sock.write_all(b"\xDE\xAD\xBE\xEF this is not a caesar frame");
        let _ = sock.flush();
        sock // keep the socket alive until the round has closed
    });
    let mut honest = Vec::new();
    for d in 0..4 {
        let cfg = cfg.clone();
        honest.push(std::thread::spawn(move || {
            let mut client = DeviceClient::new(cfg, d).unwrap();
            let mut conn = TcpConn::connect(addr).unwrap();
            client.run(&mut conn).unwrap()
        }));
    }
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30)).unwrap();
    let started = Instant::now();
    let result = svc.run().unwrap();
    assert!(
        started.elapsed() < Duration::from_secs(20),
        "poison must cut the round short, not wait out the {}s deadline",
        svc.round_timeout.as_secs()
    );
    assert_eq!(result.records.len(), 1);
    for h in honest {
        assert_eq!(h.join().unwrap(), SessionEnd::Finished);
    }
    drop(hostile.join().unwrap());
    let srv = svc.into_server();
    // BOTH devices on the poisoned socket converted, nobody else
    assert_eq!(srv.engine().stats().dropouts, 2);
    assert_eq!(srv.engine().registry().dropouts(4), 1);
    assert_eq!(srv.engine().registry().dropouts(5), 1);
    for d in 0..4 {
        assert_eq!(srv.engine().registry().completions(d), 1, "device {d}");
    }
}

#[test]
fn out_of_range_wire_ids_are_rejected_with_a_typed_frame() {
    let cfg = tiny_cfg(1);
    let server = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.socket_addr();
    let mut svc = CoordinatorService::new(server, transport);

    let rogue = std::thread::spawn(move || {
        let mut conn = TcpConn::connect(addr).unwrap();
        conn.send(&WireMsg::Join { device: 999 }).unwrap();
        conn.recv_timeout(Duration::from_secs(5)).unwrap()
    });
    // the rogue join must not count toward the rendezvous
    let err = svc.wait_for_devices(1, Duration::from_millis(800)).unwrap_err();
    assert!(format!("{err}").contains("0 of 1"), "{err}");
    assert_eq!(svc.connected(), 0);
    match rogue.join().unwrap() {
        Some(WireMsg::Reject { device: 999, code }) => {
            assert_eq!(code, reject::UNKNOWN_DEVICE)
        }
        other => panic!("expected a Reject frame, got {other:?}"),
    }
}

//! Trainer-backend parity: the AOT HLO train/eval path must match the
//! rust-native `nn/` oracle — same forward logits, and statistically
//! identical training trajectories (f32 reduction order differs, so
//! trajectories are compared with tolerance after identical batch
//! streams).
//!
//! Requires `make artifacts`; skips cleanly when missing.

use caesar_fl::coordinator::Trainer;
use caesar_fl::data::{Dataset, Shard, TaskSpec};
use caesar_fl::nn::{self, MlpSpec};
use caesar_fl::runtime::{lit_f32, to_vec_f32, Runtime};
use caesar_fl::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    Runtime::open(&Runtime::default_dir()).ok()
}

#[test]
fn eval_logits_match_native_forward() {
    let Some(rt) = runtime() else { return };
    for task in ["cifar", "har", "speech", "oppo"] {
        let spec = MlpSpec::for_task(task);
        let mut rng = Rng::new(7);
        let w = spec.init(&mut rng);
        let e = rt.manifest().eval_chunk;
        let d = spec.d_in();
        let xs: Vec<f32> = (0..e * d).map(|_| rng.normal() as f32).collect();
        let native = nn::apply(&spec, &w, &xs, e);
        let out = rt
            .exec(
                &format!("eval_{task}"),
                &[
                    lit_f32(&w, &[w.len() as i64]).unwrap(),
                    lit_f32(&xs, &[e as i64, d as i64]).unwrap(),
                ],
            )
            .unwrap();
        let xla = to_vec_f32(&out[0]).unwrap();
        assert_eq!(native.len(), xla.len());
        for (i, (a, b)) in native.iter().zip(&xla).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 + 1e-4 * a.abs(),
                "{task} logit {i}: native {a} vs xla {b}"
            );
        }
    }
}

#[test]
fn training_trajectories_agree() {
    let Some(_) = runtime() else { return };
    let task = "har";
    let spec = TaskSpec::by_name(task).unwrap();
    let ds = Dataset::generate(&spec, 600, &mut Rng::new(3));
    let shard = Shard { indices: (0..600).collect() };

    let native = Trainer::native(task);
    let xla = Trainer::xla(task, &Runtime::default_dir()).unwrap();

    let mut rng = Rng::new(5);
    let w0 = native.init_model(&mut rng);
    // tau = CHUNK and batch = a bucket size → both backends consume the
    // exact same rng-sampled batch stream
    let chunk = xla.effective_batch(16); // ensure 16 is a real bucket
    assert_eq!(chunk, 16, "bucket 16 must exist for this test");
    let tau = 5;

    let (wn, ln) = native
        .train(&w0, &ds, &shard, tau, 16, 0.05, &mut Rng::new(99))
        .unwrap();
    let (wx, lx) = xla
        .train(&w0, &ds, &shard, tau, 16, 0.05, &mut Rng::new(99))
        .unwrap();
    assert!((ln - lx).abs() < 1e-3, "loss: native {ln} vs xla {lx}");
    let mut max_diff = 0.0f32;
    for (a, b) in wn.iter().zip(&wx) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "post-training max param diff {max_diff}");
}

#[test]
fn both_backends_learn_the_same_task() {
    let Some(_) = runtime() else { return };
    let task = "har";
    let spec = TaskSpec::by_name(task).unwrap();
    let ds = Dataset::generate(&spec, 1000, &mut Rng::new(4));
    let shard = Shard { indices: (0..1000).collect() };
    for trainer in [Trainer::native(task), Trainer::xla(task, &Runtime::default_dir()).unwrap()] {
        let mut rng = Rng::new(6);
        let mut w = trainer.init_model(&mut rng);
        let before = trainer.eval(&w, &ds).unwrap();
        for _ in 0..15 {
            let (w2, _) = trainer.train(&w, &ds, &shard, 10, 16, 0.05, &mut rng).unwrap();
            w = w2;
        }
        let after = trainer.eval(&w, &ds).unwrap();
        assert!(
            after.accuracy > before.accuracy + 0.2,
            "{:?}: {} -> {}",
            trainer.n_params(),
            before.accuracy,
            after.accuracy
        );
    }
}

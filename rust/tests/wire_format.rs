//! Wire-format acceptance pins:
//!
//! 1. for every codec and shape, `decode(encode(x))` is bit-identical to
//!    the pre-refactor eager dense result (the legacy loops are
//!    re-implemented here, independent of the production code);
//! 2. the measured `len_bits()` equals the legacy `traffic::*_bits`
//!    closed forms;
//! 3. sparse payload aggregation folds to the exact same f64 sums as the
//!    dense path, so engine parity holds with sparse aggregation enabled.

use caesar_fl::compress::{caesar_model, quant, topk, traffic};
use caesar_fl::coordinator::CodecEngine;
use caesar_fl::engine::{AggregatorShard, ShardReducer};
use caesar_fl::schemes::{DownloadCodec, UploadCodec};
use caesar_fl::util::rng::Rng;
use caesar_fl::wire::{legacy_bits, Payload};

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

/// The pre-refactor eager Top-K (dense vector, dropped entries zeroed).
fn legacy_topk_dense(g: &[f32], ratio: f64) -> (Vec<f32>, usize) {
    let n = g.len();
    let (thr, drop) = topk::keep_threshold(g, ratio);
    if drop >= n {
        return (vec![0.0; n], 0);
    }
    let mut dense = vec![0.0f32; n];
    let mut kept = 0usize;
    for i in 0..n {
        if g[i].abs() >= thr {
            dense[i] = g[i];
            kept += 1;
        }
    }
    (dense, kept)
}

/// The pre-refactor eager element-wise quantizer.
fn legacy_quantize(x: &[f32], levels: u32, noise: &[f32]) -> Vec<f32> {
    let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    if norm == 0.0 {
        return vec![0.0; x.len()];
    }
    let s = levels as f32;
    x.iter()
        .zip(noise)
        .map(|(&xi, &u)| {
            let scaled = xi.abs() / norm * s;
            let q = (scaled + u).floor().min(s);
            let sign = if xi >= 0.0 { 1.0 } else { -1.0 };
            sign * q / s * norm
        })
        .collect()
}

const SHAPES: [usize; 5] = [1, 7, 256, 777, 4096];

#[test]
fn topk_wire_matches_legacy_dense_and_formula_every_shape() {
    for (si, &n) in SHAPES.iter().enumerate() {
        let g = randn(n, 0x70 + si as u64);
        for ratio in [0.0, 0.1, 0.5, 0.9, 1.0] {
            let (payload, _) = topk::topk_encode(&g, ratio);
            let enc = payload.encode();
            let back = enc.decode();
            assert_eq!(back, payload, "n={n} ratio={ratio}");
            let (legacy, kept) = legacy_topk_dense(&g, ratio);
            assert_bits_eq(&back.to_dense(), &legacy, &format!("n={n} ratio={ratio}"));
            assert_eq!(enc.bits, traffic::topk_grad_bits(n, kept), "n={n} ratio={ratio}");
            assert_eq!(enc.bits, legacy_bits(&payload));
        }
    }
}

#[test]
fn quant_wire_matches_legacy_dense_and_formula_every_shape() {
    for (si, &n) in SHAPES.iter().enumerate() {
        let x = randn(n, 0x9A + si as u64);
        let noise: Vec<f32> = {
            let mut rng = Rng::new(0x9B + si as u64);
            (0..n).map(|_| rng.f32()).collect()
        };
        for bits in [1u32, 4, 12, 28] {
            let levels = quant::levels_for_bits(bits);
            let (norm, codes) = quant::quantize_codes(&x, levels, Some(&noise));
            let payload = Payload::Quant { bits, levels, norm, codes };
            let enc = payload.encode();
            let back = enc.decode();
            assert_eq!(back, payload, "n={n} bits={bits}");
            let legacy = legacy_quantize(&x, levels, &noise);
            assert_bits_eq(&back.to_dense(), &legacy, &format!("n={n} bits={bits}"));
            assert_eq!(enc.bits, traffic::quantized_bits(n, bits), "n={n} bits={bits}");
        }
    }
}

#[test]
fn caesar_wire_matches_compressed_model_and_formula_every_shape() {
    for (si, &n) in SHAPES.iter().enumerate() {
        let w = randn(n, 0xCA + si as u64);
        for ratio in [0.0, 0.35, 0.6, 1.0] {
            let cm = caesar_model::caesar_compress(&w, ratio);
            let payload = Payload::CaesarSplit(cm.clone());
            let enc = payload.encode();
            assert_eq!(enc.decode(), payload, "n={n} ratio={ratio}");
            assert_eq!(
                enc.bits,
                traffic::caesar_model_bits(n, cm.n_quantized()),
                "n={n} ratio={ratio}"
            );
            // the standalone CompressedModel byte codec is the same stream
            assert_eq!(enc.bytes, cm.encode(), "n={n} ratio={ratio}");
        }
    }
}

#[test]
fn dense_wire_matches_formula() {
    let w = randn(777, 0xDE);
    let payload = Payload::Dense(w.clone());
    let enc = payload.encode();
    assert_eq!(enc.bits, traffic::full_model_bits(777));
    assert_bits_eq(&enc.decode().to_dense(), &w, "dense");
}

#[test]
fn codec_engine_reports_measured_lengths() {
    let e = CodecEngine::native();
    let w = randn(1023, 1);
    let local = randn(1023, 2);
    for codec in [
        DownloadCodec::Full,
        DownloadCodec::CaesarSplit { ratio: 0.35 },
        DownloadCodec::TopK { ratio: 0.5 },
        DownloadCodec::Quant { bits: 8 },
    ] {
        let enc = e.encode_download(codec, &w, &mut Rng::new(5)).unwrap();
        // bytes really carry the payload: a decode from the bytes alone
        // (plus the out-of-band spec) reproduces the recovered model
        let r = e.download(codec, &w, Some(&local), &mut Rng::new(5)).unwrap();
        assert_eq!(enc.bits, r.wire_bits, "{codec:?}");
        assert_eq!(enc.len_bytes(), enc.bits.div_ceil(8), "{codec:?}");
        let via_bytes = e.recover_download(&enc, Some(&local)).unwrap();
        assert_bits_eq(&via_bytes, &r.model, &format!("{codec:?}"));
    }
}

#[test]
fn recover_download_into_is_bit_identical_to_recover_download() {
    let e = CodecEngine::native();
    // one REUSED output buffer across every codec, shape and local-model
    // state: proves recover_download_into clears/overwrites correctly
    let mut out: Vec<f32> = vec![f32::NAN; 9];
    for (si, &n) in SHAPES.iter().enumerate() {
        let w = randn(n, 0x1A + si as u64);
        let local = randn(n, 0x2B + si as u64);
        for codec in [
            DownloadCodec::Full,
            DownloadCodec::CaesarSplit { ratio: 0.35 },
            DownloadCodec::CaesarSplit { ratio: 1.0 },
            DownloadCodec::TopK { ratio: 0.5 },
            DownloadCodec::TopK { ratio: 1.0 },
            DownloadCodec::Quant { bits: 8 },
        ] {
            for with_local in [true, false] {
                let enc = e.encode_download(codec, &w, &mut Rng::new(si as u64)).unwrap();
                let l = with_local.then_some(&local[..]);
                let want = e.recover_download(&enc, l).unwrap();
                e.recover_download_into(&enc, l, &mut out).unwrap();
                assert_bits_eq(
                    &out,
                    &want,
                    &format!("n={n} {codec:?} local={with_local}"),
                );
            }
        }
    }
}

#[test]
fn fold_encoded_is_bit_identical_to_decoded_folds() {
    let n = 1024;
    let e = CodecEngine::native();
    let devices: Vec<usize> = (0..9).collect();
    let mut payload_shard = AggregatorShard::new(0, n, devices.clone());
    let mut encoded_shard = AggregatorShard::new(0, n, devices.clone());
    for &d in &devices {
        let g = randn(n, 0xE0 + d as u64);
        let codec = match d % 3 {
            0 => UploadCodec::TopK { ratio: 0.9 },
            1 => UploadCodec::Full,
            _ => UploadCodec::Quant { bits: 6 },
        };
        let enc = e.encode_upload(codec, &g, &mut Rng::new(d as u64)).unwrap();
        payload_shard.fold_payload(d, &enc.decode(), 0.31);
        encoded_shard.fold_encoded(d, &enc, 0.31);
    }
    let total = |shard: AggregatorShard| -> Vec<f64> {
        let mut r = ShardReducer::new(n, 1);
        r.push(shard).unwrap();
        r.finish().unwrap().0.to_vec()
    };
    let a = total(payload_shard);
    let b = total(encoded_shard);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "elem {i}: {x} vs {y}");
    }
}

#[test]
fn sparse_and_dense_aggregation_agree_bit_exactly() {
    let n = 2048;
    let devices: Vec<usize> = (0..10).collect();
    let e = CodecEngine::native();
    let mut dense_shard = AggregatorShard::new(0, n, devices.clone());
    let mut sparse_shard = AggregatorShard::new(0, n, devices.clone());
    for &d in &devices {
        let g = randn(n, 0xA0 + d as u64);
        let codec = match d % 3 {
            0 => UploadCodec::TopK { ratio: 0.9 },
            1 => UploadCodec::Full,
            _ => UploadCodec::Quant { bits: 4 },
        };
        let enc = e.encode_upload(codec, &g, &mut Rng::new(d as u64)).unwrap();
        let payload = enc.decode();
        dense_shard.fold(d, &payload.to_dense(), 1.0);
        sparse_shard.fold_payload(d, &payload, 1.0);
    }
    assert!(dense_shard.complete() && sparse_shard.complete());
    assert_eq!(dense_shard.folded(), sparse_shard.folded());
    // the two shards walked the same canonical reduction tree: the reduced
    // f64 totals are bit-identical
    let total = |shard: AggregatorShard| -> Vec<f64> {
        let mut r = ShardReducer::new(n, 1);
        r.push(shard).unwrap();
        r.finish().unwrap().0.to_vec()
    };
    let a = total(dense_shard);
    let b = total(sparse_shard);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "elem {i}: {x} vs {y}");
    }
}

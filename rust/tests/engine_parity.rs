//! Engine determinism contract: for a fixed seed, the event-driven round
//! engine produces BIT-IDENTICAL results for any worker count — the
//! parallel path is indistinguishable from the sequential
//! `Server::round()` driver — and mid-round dropouts are excluded from
//! aggregation with consistent staleness/participation tracking.

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::engine::Phase;
use caesar_fl::schemes;

fn tiny_cfg(task: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(task);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    cfg.rounds = rounds;
    cfg.n_train = 1200;
    cfg.n_test = 300;
    cfg.tau = 4;
    cfg.alpha = 0.2;
    cfg.eval_every = 1;
    cfg
}

fn run_with_workers(task: &str, scheme: &str, rounds: usize, workers: usize) -> Server {
    let mut cfg = tiny_cfg(task, rounds);
    cfg.engine.workers = workers;
    let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
    srv.run().unwrap();
    srv
}

/// f32 slices compared by bit pattern — NaN-safe and stricter than `==`.
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

#[test]
fn parallel_engine_is_bit_identical_to_sequential() {
    // prowd matters here: its Quant downloads draw device-stream noise,
    // so they bypass the download-encode cache and must stay per-device
    for scheme in ["fedavg", "caesar", "prowd"] {
        let seq = run_with_workers("har", scheme, 5, 1);
        let par = run_with_workers("har", scheme, 5, 4);
        assert_bits_eq(&seq.global, &par.global, scheme);
    }
}

#[test]
fn download_encode_cache_shares_work_without_changing_results() {
    // every device sharing a codec receives the SAME Arc'd bytes, so
    // encode executions scale with distinct codecs — and the counts are
    // deterministic across worker counts (misses encode under the lock)
    let seq = run_with_workers("har", "caesar", 5, 1);
    let par = run_with_workers("har", "caesar", 5, 6);
    assert_bits_eq(&seq.global, &par.global, "cache parity");
    let (s, p) = (seq.engine().stats(), par.engine().stats());
    assert_eq!(s.download_requests, p.download_requests, "requests must match");
    assert_eq!(s.download_encodes, p.download_encodes, "encodes must match");
    assert!(s.download_requests > 0);
    // caesar's staleness clustering (cfg.clusters = 4) plus Full for
    // first-timers: at most 5 distinct download codecs per round
    let rounds = 5;
    assert!(
        s.download_encodes <= 5 * rounds,
        "encodes {} exceed distinct-codec bound {}",
        s.download_encodes,
        5 * rounds
    );
    assert!(
        s.download_encodes < s.download_requests,
        "cache never hit: {} encodes for {} requests",
        s.download_encodes,
        s.download_requests
    );
}

#[test]
fn fedavg_encodes_once_per_round_for_all_participants() {
    // the degenerate sharing case: every participant downloads Full
    let srv = run_with_workers("har", "fedavg", 4, 3);
    let stats = srv.engine().stats();
    assert_eq!(stats.download_encodes, 4, "one Full encode per round");
    assert_eq!(
        stats.download_requests % 4,
        0,
        "each round serves every participant"
    );
    assert!(stats.download_requests > stats.download_encodes);
}

#[test]
fn quant_downloads_bypass_the_cache() {
    // prowd's Quant download draws per-device noise: every request must
    // be a real encode
    let srv = run_with_workers("har", "prowd", 3, 2);
    let stats = srv.engine().stats();
    assert!(stats.download_requests > 0);
    assert_eq!(
        stats.download_encodes, stats.download_requests,
        "quant payloads are device-specific and must never be shared"
    );
}

#[test]
fn every_worker_count_matches_including_odd_ones() {
    let seq = run_with_workers("har", "caesar", 3, 1);
    for workers in [2, 3, 7] {
        let par = run_with_workers("har", "caesar", 3, workers);
        assert_bits_eq(&seq.global, &par.global, &format!("workers={workers}"));
    }
}

#[test]
fn traffic_and_clock_match_across_worker_counts() {
    let run = |workers: usize| {
        let mut cfg = tiny_cfg("har", 4);
        cfg.engine.workers = workers;
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        srv.run().unwrap()
    };
    let a = run(1);
    let b = run(8);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.traffic_gb.to_bits(), rb.traffic_gb.to_bits(), "round {}", ra.t);
        assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits(), "round {}", ra.t);
        assert_eq!(ra.mean_loss.to_bits(), rb.mean_loss.to_bits(), "round {}", ra.t);
    }
}

#[test]
fn agg_group_is_part_of_the_contract_not_the_worker_count() {
    // changing agg_group changes the reduction tree (like changing batch
    // order would) — but for a FIXED agg_group every worker count agrees
    let run = |workers: usize, group: usize| {
        let mut cfg = tiny_cfg("har", 3);
        cfg.engine.workers = workers;
        cfg.engine.agg_group = group;
        let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
        srv.run().unwrap();
        srv
    };
    let a = run(1, 3);
    let b = run(5, 3);
    assert_bits_eq(&a.global, &b.global, "group=3");
}

#[test]
fn tree_reduction_matches_single_pass_at_any_worker_count() {
    // the fixed-shape reduction tree and chunk-sharding must be
    // invisible: workers = 1 executes the tree streaming on the
    // coordinator thread (the single-pass reducer), workers > 1 fans the
    // pairwise combines over the pool — same shape, same bits. Dropouts
    // are live and the schemes cover every upload codec family (caesar
    // = Top-K, prowd = Quant, fedavg = Dense), at agg_group = 3 so the
    // tree has uneven levels with promoted lone nodes.
    for scheme in ["caesar", "prowd", "fedavg"] {
        let run = |workers: usize, chunk: usize| {
            let mut cfg = tiny_cfg("har", 4);
            cfg.engine.workers = workers;
            cfg.engine.agg_group = 3;
            cfg.engine.agg_chunk = chunk;
            cfg.engine.dropout_rate = 0.25;
            let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
            let res = srv.run().unwrap();
            (srv, res)
        };
        // single-pass baseline: serial streaming walk, unchunked buffers
        let (base, base_res) = run(1, 0);
        for (workers, chunk) in [(1usize, 64usize), (3, 0), (3, 64), (8, 1024)] {
            let (srv, res) = run(workers, chunk);
            let what = format!("{scheme} workers={workers} chunk={chunk}");
            // final model bits
            assert_bits_eq(&base.global, &srv.global, &what);
            // traffic ledger and per-round records
            assert_eq!(base_res.records.len(), res.records.len(), "{what}");
            for (ra, rb) in base_res.records.iter().zip(&res.records) {
                assert_eq!(
                    ra.traffic_gb.to_bits(),
                    rb.traffic_gb.to_bits(),
                    "{what} round {}",
                    ra.t
                );
                assert_eq!(
                    ra.sim_time_s.to_bits(),
                    rb.sim_time_s.to_bits(),
                    "{what} round {}",
                    ra.t
                );
                assert_eq!(
                    ra.mean_loss.to_bits(),
                    rb.mean_loss.to_bits(),
                    "{what} round {}",
                    ra.t
                );
            }
            assert_eq!(
                base.engine().stats().dropouts,
                srv.engine().stats().dropouts,
                "{what}"
            );
        }
    }
}

#[test]
fn engine_runs_all_schemes_in_parallel_mode() {
    for scheme in ["flexcom", "prowd", "pyramidfl", "caesar-br", "caesar-dc"] {
        let srv = run_with_workers("har", scheme, 2, 4);
        assert_eq!(srv.engine().stats().rounds, 2, "{scheme}");
        assert_eq!(srv.engine().phase(), Phase::Standby, "{scheme}");
    }
}

#[test]
fn dropouts_are_excluded_and_tracking_stays_consistent() {
    let rounds = 6;
    let mut cfg = tiny_cfg("har", rounds);
    cfg.engine.workers = 4;
    cfg.engine.dropout_rate = 0.4;
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    let r = srv.run().unwrap();
    assert_eq!(r.records.len(), rounds);
    let stats = srv.engine().stats();
    assert!(stats.dropouts > 0, "40% dropout over 6 rounds must hit someone");
    // a dropped device sent no EndRound: completions + dropouts account for
    // every StartRound the registry saw, and the participation tracker's
    // staleness only resets for completers
    let reg = srv.engine().registry();
    for d in 0..reg.len() {
        let started = reg.completions(d) + reg.dropouts(d);
        if srv.tracker().never_participated(d) {
            // never completed: every start (if any) ended in dropout
            assert_eq!(reg.completions(d), 0, "device {d}");
            assert_eq!(started, reg.dropouts(d), "device {d}");
        } else {
            assert!(reg.completions(d) > 0, "device {d} tracked but never completed");
            let s = srv.tracker().staleness(d, rounds + 1);
            assert!((1..=rounds).contains(&s), "device {d} staleness {s}");
        }
    }
}

#[test]
fn full_dropout_means_the_model_never_moves() {
    let mut cfg = tiny_cfg("har", 3);
    cfg.engine.workers = 2;
    cfg.engine.dropout_rate = 1.0;
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    let before = srv.global.clone();
    let r = srv.run().unwrap();
    assert_bits_eq(&before, &srv.global, "all-dropout run");
    // downloads still cost traffic; uploads never happen
    assert!(r.total_traffic_gb() > 0.0);
    // every device the registry saw this run is dropped or untouched
    let reg = srv.engine().registry();
    for d in 0..reg.len() {
        assert_eq!(reg.completions(d), 0, "device {d}");
        assert!(srv.tracker().never_participated(d), "device {d}");
    }
}

#[test]
fn dropout_rounds_are_deterministic_across_worker_counts() {
    let run = |workers: usize| {
        let mut cfg = tiny_cfg("har", 4);
        cfg.engine.workers = workers;
        cfg.engine.dropout_rate = 0.3;
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        srv.run().unwrap();
        srv
    };
    let a = run(1);
    let b = run(6);
    assert_bits_eq(&a.global, &b.global, "dropout determinism");
    assert_eq!(a.engine().stats().dropouts, b.engine().stats().dropouts);
}

#[test]
fn trainer_builds_stay_flat_across_rounds() {
    // the persistent pool builds one trainer per WORKER per RUN — the
    // pre-pool engine paid one per worker per ROUND (workers·rounds)
    let srv = run_with_workers("har", "caesar", 5, 4);
    let stats = srv.engine().stats();
    assert_eq!(stats.rounds, 5);
    assert!(
        (1..=4).contains(&stats.trainer_builds),
        "builds {} must stay <= workers (4), not workers*rounds (20)",
        stats.trainer_builds
    );
    // inline executor: exactly one trainer for the whole run
    let seq = run_with_workers("har", "caesar", 5, 1);
    assert_eq!(seq.engine().stats().trainer_builds, 1);
}

#[test]
fn unchanged_model_reuses_download_encodes_across_rounds() {
    // all-dropout rounds never move the global model, so the engine's
    // generation-keyed cache serves rounds 2..R from round 1's encode
    let rounds = 3;
    let mut cfg = tiny_cfg("har", rounds);
    cfg.engine.workers = 2;
    cfg.engine.dropout_rate = 1.0;
    let k = cfg.participants_per_round();
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    srv.run().unwrap();
    let stats = srv.engine().stats();
    // every participant still pulled its download before vanishing
    assert_eq!(stats.download_requests, rounds * k);
    assert_eq!(stats.download_encodes, 1, "one Full encode for the whole run");
    assert_eq!(
        stats.cache_cross_round_hits,
        (rounds - 1) * k,
        "rounds after the first must be served from the carried entry"
    );
}

#[test]
fn worker_panic_surfaces_as_error_and_next_round_runs() {
    use caesar_fl::compress::traffic::PayloadScale;
    use caesar_fl::config::{CompressionBackend as CB, EngineConfig};
    use caesar_fl::coordinator::Trainer;
    use caesar_fl::data::{partition, Dataset, TaskSpec};
    use caesar_fl::engine::{Engine, ExecutorHandle, Phase as P, RoundEnv, StartRound, WorkerCtx};
    use caesar_fl::schemes::{DevicePlan, DownloadCodec, UploadCodec};
    use caesar_fl::util::rng::Rng;
    use caesar_fl::util::threadpool::WorkerPool;

    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CB::Native;
    let ds = Dataset::generate(&TaskSpec::by_name("har").unwrap(), 64, &mut Rng::new(0));
    let mut part = partition(&ds, 4, 0.0, &mut Rng::new(1));
    // device 0's shard is emptied: Trainer::train asserts on it, so the
    // worker that picks device 0 up PANICS mid-round
    part.shards[0].indices.clear();
    let n_params = Trainer::native("har").n_params();
    let global = vec![0.0f32; n_params];
    let locals: Vec<Option<Vec<f32>>> = vec![None; 4];
    let scale = PayloadScale::identity(n_params);
    let item = |t: usize, d: usize| StartRound {
        t,
        plan: DevicePlan {
            device: d,
            download: DownloadCodec::Full,
            upload: UploadCodec::Full,
            batch: 4,
            tau: 1,
        },
        beta_d: 1e6,
        beta_u: 1e6,
        mu: 1e-6,
    };
    let env = |t: usize| RoundEnv {
        t,
        lr: 0.1,
        cfg: &cfg,
        global: &global,
        model_version: 0,
        locals: &locals,
        train_ds: &ds,
        partition: &part,
        scale: &scale,
        stream_base: 42,
        sim_now_s: 0.0,
    };
    // explicit 2-thread pool (not host-clamped) so a survivor remains
    let pool = WorkerPool::new(2, |_wi| Ok(WorkerCtx { trainer: Trainer::native("har") }))
        .unwrap();
    let exec = ExecutorHandle::Pool(pool);
    let ecfg = EngineConfig {
        workers: 2,
        agg_group: 1,
        heartbeat_s: 0.0,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(ecfg, 4);

    // round 1 includes the poisoned device: the panic surfaces as an
    // error event — no hang, no deadlock — and the round fails cleanly
    let err = engine
        .execute_round(&env(1), &[item(1, 0), item(1, 1)], &exec)
        .unwrap_err();
    assert!(
        format!("{err}").contains("worker"),
        "panic must surface as a worker error, got: {err}"
    );
    assert_eq!(engine.phase(), P::Standby, "a failed round still returns to Standby");

    // round 2 on healthy devices executes on the surviving worker
    let out = engine
        .execute_round(&env(2), &[item(2, 1), item(2, 2), item(2, 3)], &exec)
        .unwrap();
    assert_eq!(out.updates.len(), 3);
    assert!(out.dropped.is_empty());
    // the pool never rebuilt anything: builds stay at the 2 setup ones
    assert_eq!(exec.trainer_builds(), 2);
    assert_eq!(engine.stats().trainer_builds, 2);
    // finish() runs the accounting tripwire and joins nothing it
    // shouldn't — dropping `exec` afterwards joins the pool threads
    engine.finish();
    assert_eq!(engine.phase(), P::Finished);
    drop(exec);
}

// ---------------------------------------------------------------------
// semi-async pipelined rounds
// ---------------------------------------------------------------------

fn run_pipelined(
    scheme: &str,
    rounds: usize,
    workers: usize,
    depth: usize,
    bound: usize,
) -> (Server, caesar_fl::coordinator::RunResult) {
    let mut cfg = tiny_cfg("har", rounds);
    cfg.engine.workers = workers;
    cfg.engine.pipeline_depth = depth;
    cfg.engine.staleness_bound = bound;
    let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
    let res = srv.run().unwrap();
    (srv, res)
}

#[test]
fn depth_one_bound_zero_is_the_barrier_engine() {
    // the explicit knob values must route to (and therefore bit-match)
    // the legacy barrier loop
    let (barrier, barrier_res) = run_pipelined("caesar", 4, 2, 1, 0);
    let mut cfg = tiny_cfg("har", 4);
    cfg.engine.workers = 2;
    let mut legacy = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
    let legacy_res = legacy.run().unwrap();
    assert_bits_eq(&legacy.global, &barrier.global, "depth-1 routing");
    for (ra, rb) in legacy_res.records.iter().zip(&barrier_res.records) {
        assert_eq!(ra.traffic_gb.to_bits(), rb.traffic_gb.to_bits(), "round {}", ra.t);
        assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits(), "round {}", ra.t);
        assert_eq!(ra.mean_loss.to_bits(), rb.mean_loss.to_bits(), "round {}", ra.t);
    }
}

#[test]
fn pipelined_rounds_are_bit_identical_across_worker_counts() {
    // the tentpole determinism pin: depth 2 with a live staleness buffer,
    // same seed → same final model bits, traffic ledger and records at
    // every worker count
    for scheme in ["caesar", "fedavg"] {
        let (base, base_res) = run_pipelined(scheme, 6, 1, 2, 2);
        assert_eq!(base_res.records.len(), 6, "{scheme}");
        for workers in [3usize, 8] {
            let (srv, res) = run_pipelined(scheme, 6, workers, 2, 2);
            let what = format!("{scheme} workers={workers}");
            assert_bits_eq(&base.global, &srv.global, &what);
            assert_eq!(base_res.records.len(), res.records.len(), "{what}");
            for (ra, rb) in base_res.records.iter().zip(&res.records) {
                assert_eq!(
                    ra.traffic_gb.to_bits(),
                    rb.traffic_gb.to_bits(),
                    "{what} round {}",
                    ra.t
                );
                assert_eq!(
                    ra.sim_time_s.to_bits(),
                    rb.sim_time_s.to_bits(),
                    "{what} round {}",
                    ra.t
                );
                assert_eq!(
                    ra.mean_loss.to_bits(),
                    rb.mean_loss.to_bits(),
                    "{what} round {}",
                    ra.t
                );
            }
        }
    }
}

#[test]
fn pipelined_runs_complete_with_dropouts_and_deep_windows() {
    // deeper window + dropouts: every round still closes, the engine
    // returns to Standby, and the run is reproducible
    let run = |workers: usize| {
        let mut cfg = tiny_cfg("har", 6);
        cfg.engine.workers = workers;
        cfg.engine.pipeline_depth = 3;
        cfg.engine.staleness_bound = 2;
        cfg.engine.dropout_rate = 0.3;
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        let res = srv.run().unwrap();
        (srv, res)
    };
    let (a, ares) = run(1);
    let (b, bres) = run(6);
    assert_eq!(a.engine().stats().rounds, 6);
    assert_eq!(a.engine().phase(), Phase::Standby);
    assert_eq!(ares.records.len(), bres.records.len());
    assert_bits_eq(&a.global, &b.global, "deep window + dropouts");
    assert_eq!(a.engine().stats().dropouts, b.engine().stats().dropouts);
}

#[test]
fn dead_workers_are_respawned_through_the_original_setup() {
    use caesar_fl::coordinator::Trainer;
    use caesar_fl::engine::{ExecutorHandle, WorkerCtx};
    use caesar_fl::util::threadpool::WorkerPool;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    // a pool whose first batch kills one worker: the engine-facing handle
    // must report the casualty and rebuild it with the ORIGINAL setup
    let setups = Arc::new(AtomicUsize::new(0));
    let s2 = Arc::clone(&setups);
    let pool = WorkerPool::new(2, move |_wi| {
        s2.fetch_add(1, Ordering::SeqCst);
        Ok(WorkerCtx { trainer: Trainer::native("har") })
    })
    .unwrap();
    let mut exec = ExecutorHandle::Pool(pool);
    assert_eq!(exec.worker_census(), (2, 2));
    assert_eq!(setups.load(Ordering::SeqCst), 2);

    // kill one worker with a poison batch item
    if let ExecutorHandle::Pool(p) = &exec {
        let mut lost = 0usize;
        p.run_batch(
            2,
            |_ctx: &mut WorkerCtx, i: usize| {
                if i == 0 {
                    panic!("poison item");
                }
                i
            },
            |r| {
                if r.is_err() {
                    lost += 1;
                }
            },
        );
        assert_eq!(lost, 1, "exactly the poison item is reported lost");
    }
    assert_eq!(exec.worker_census().1, 1, "the poisoned worker must be retired");

    // respawn: one rebuild, through the stored setup closure
    assert_eq!(exec.respawn_dead().unwrap(), 1);
    assert_eq!(exec.worker_census(), (2, 2));
    assert_eq!(setups.load(Ordering::SeqCst), 3, "respawn must re-run the setup");
    // healthy pool: respawn is a no-op
    assert_eq!(exec.respawn_dead().unwrap(), 0);
    drop(exec);
}

#[test]
fn heartbeats_flow_and_liveness_is_tracked() {
    let mut cfg = tiny_cfg("har", 2);
    cfg.engine.workers = 2;
    cfg.engine.heartbeat_s = 5.0;
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    srv.run().unwrap();
    let stats = srv.engine().stats();
    // simulated rounds last tens of seconds → heartbeats must have flowed
    assert!(stats.heartbeats > 0, "no heartbeats at 5s interval");
    assert!(stats.messages > stats.heartbeats);
}

//! Engine determinism contract: for a fixed seed, the event-driven round
//! engine produces BIT-IDENTICAL results for any worker count — the
//! parallel path is indistinguishable from the sequential
//! `Server::round()` driver — and mid-round dropouts are excluded from
//! aggregation with consistent staleness/participation tracking.

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::engine::Phase;
use caesar_fl::schemes;

fn tiny_cfg(task: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(task);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    cfg.rounds = rounds;
    cfg.n_train = 1200;
    cfg.n_test = 300;
    cfg.tau = 4;
    cfg.alpha = 0.2;
    cfg.eval_every = 1;
    cfg
}

fn run_with_workers(task: &str, scheme: &str, rounds: usize, workers: usize) -> Server {
    let mut cfg = tiny_cfg(task, rounds);
    cfg.engine.workers = workers;
    let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
    srv.run().unwrap();
    srv
}

/// f32 slices compared by bit pattern — NaN-safe and stricter than `==`.
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

#[test]
fn parallel_engine_is_bit_identical_to_sequential() {
    // prowd matters here: its Quant downloads draw device-stream noise,
    // so they bypass the download-encode cache and must stay per-device
    for scheme in ["fedavg", "caesar", "prowd"] {
        let seq = run_with_workers("har", scheme, 5, 1);
        let par = run_with_workers("har", scheme, 5, 4);
        assert_bits_eq(&seq.global, &par.global, scheme);
    }
}

#[test]
fn download_encode_cache_shares_work_without_changing_results() {
    // every device sharing a codec receives the SAME Arc'd bytes, so
    // encode executions scale with distinct codecs — and the counts are
    // deterministic across worker counts (misses encode under the lock)
    let seq = run_with_workers("har", "caesar", 5, 1);
    let par = run_with_workers("har", "caesar", 5, 6);
    assert_bits_eq(&seq.global, &par.global, "cache parity");
    let (s, p) = (seq.engine().stats(), par.engine().stats());
    assert_eq!(s.download_requests, p.download_requests, "requests must match");
    assert_eq!(s.download_encodes, p.download_encodes, "encodes must match");
    assert!(s.download_requests > 0);
    // caesar's staleness clustering (cfg.clusters = 4) plus Full for
    // first-timers: at most 5 distinct download codecs per round
    let rounds = 5;
    assert!(
        s.download_encodes <= 5 * rounds,
        "encodes {} exceed distinct-codec bound {}",
        s.download_encodes,
        5 * rounds
    );
    assert!(
        s.download_encodes < s.download_requests,
        "cache never hit: {} encodes for {} requests",
        s.download_encodes,
        s.download_requests
    );
}

#[test]
fn fedavg_encodes_once_per_round_for_all_participants() {
    // the degenerate sharing case: every participant downloads Full
    let srv = run_with_workers("har", "fedavg", 4, 3);
    let stats = srv.engine().stats();
    assert_eq!(stats.download_encodes, 4, "one Full encode per round");
    assert_eq!(
        stats.download_requests % 4,
        0,
        "each round serves every participant"
    );
    assert!(stats.download_requests > stats.download_encodes);
}

#[test]
fn quant_downloads_bypass_the_cache() {
    // prowd's Quant download draws per-device noise: every request must
    // be a real encode
    let srv = run_with_workers("har", "prowd", 3, 2);
    let stats = srv.engine().stats();
    assert!(stats.download_requests > 0);
    assert_eq!(
        stats.download_encodes, stats.download_requests,
        "quant payloads are device-specific and must never be shared"
    );
}

#[test]
fn every_worker_count_matches_including_odd_ones() {
    let seq = run_with_workers("har", "caesar", 3, 1);
    for workers in [2, 3, 7] {
        let par = run_with_workers("har", "caesar", 3, workers);
        assert_bits_eq(&seq.global, &par.global, &format!("workers={workers}"));
    }
}

#[test]
fn traffic_and_clock_match_across_worker_counts() {
    let run = |workers: usize| {
        let mut cfg = tiny_cfg("har", 4);
        cfg.engine.workers = workers;
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        srv.run().unwrap()
    };
    let a = run(1);
    let b = run(8);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.traffic_gb.to_bits(), rb.traffic_gb.to_bits(), "round {}", ra.t);
        assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits(), "round {}", ra.t);
        assert_eq!(ra.mean_loss.to_bits(), rb.mean_loss.to_bits(), "round {}", ra.t);
    }
}

#[test]
fn agg_group_is_part_of_the_contract_not_the_worker_count() {
    // changing agg_group changes the reduction tree (like changing batch
    // order would) — but for a FIXED agg_group every worker count agrees
    let run = |workers: usize, group: usize| {
        let mut cfg = tiny_cfg("har", 3);
        cfg.engine.workers = workers;
        cfg.engine.agg_group = group;
        let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
        srv.run().unwrap();
        srv
    };
    let a = run(1, 3);
    let b = run(5, 3);
    assert_bits_eq(&a.global, &b.global, "group=3");
}

#[test]
fn engine_runs_all_schemes_in_parallel_mode() {
    for scheme in ["flexcom", "prowd", "pyramidfl", "caesar-br", "caesar-dc"] {
        let srv = run_with_workers("har", scheme, 2, 4);
        assert_eq!(srv.engine().stats().rounds, 2, "{scheme}");
        assert_eq!(srv.engine().phase(), Phase::Standby, "{scheme}");
    }
}

#[test]
fn dropouts_are_excluded_and_tracking_stays_consistent() {
    let rounds = 6;
    let mut cfg = tiny_cfg("har", rounds);
    cfg.engine.workers = 4;
    cfg.engine.dropout_rate = 0.4;
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    let r = srv.run().unwrap();
    assert_eq!(r.records.len(), rounds);
    let stats = srv.engine().stats();
    assert!(stats.dropouts > 0, "40% dropout over 6 rounds must hit someone");
    // a dropped device sent no EndRound: completions + dropouts account for
    // every StartRound the registry saw, and the participation tracker's
    // staleness only resets for completers
    let reg = srv.engine().registry();
    for d in 0..reg.len() {
        let started = reg.completions(d) + reg.dropouts(d);
        if srv.tracker().never_participated(d) {
            // never completed: every start (if any) ended in dropout
            assert_eq!(reg.completions(d), 0, "device {d}");
            assert_eq!(started, reg.dropouts(d), "device {d}");
        } else {
            assert!(reg.completions(d) > 0, "device {d} tracked but never completed");
            let s = srv.tracker().staleness(d, rounds + 1);
            assert!((1..=rounds).contains(&s), "device {d} staleness {s}");
        }
    }
}

#[test]
fn full_dropout_means_the_model_never_moves() {
    let mut cfg = tiny_cfg("har", 3);
    cfg.engine.workers = 2;
    cfg.engine.dropout_rate = 1.0;
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    let before = srv.global.clone();
    let r = srv.run().unwrap();
    assert_bits_eq(&before, &srv.global, "all-dropout run");
    // downloads still cost traffic; uploads never happen
    assert!(r.total_traffic_gb() > 0.0);
    // every device the registry saw this run is dropped or untouched
    let reg = srv.engine().registry();
    for d in 0..reg.len() {
        assert_eq!(reg.completions(d), 0, "device {d}");
        assert!(srv.tracker().never_participated(d), "device {d}");
    }
}

#[test]
fn dropout_rounds_are_deterministic_across_worker_counts() {
    let run = |workers: usize| {
        let mut cfg = tiny_cfg("har", 4);
        cfg.engine.workers = workers;
        cfg.engine.dropout_rate = 0.3;
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        srv.run().unwrap();
        srv
    };
    let a = run(1);
    let b = run(6);
    assert_bits_eq(&a.global, &b.global, "dropout determinism");
    assert_eq!(a.engine().stats().dropouts, b.engine().stats().dropouts);
}

#[test]
fn heartbeats_flow_and_liveness_is_tracked() {
    let mut cfg = tiny_cfg("har", 2);
    cfg.engine.workers = 2;
    cfg.engine.heartbeat_s = 5.0;
    let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    srv.run().unwrap();
    let stats = srv.engine().stats();
    // simulated rounds last tens of seconds → heartbeats must have flowed
    assert!(stats.heartbeats > 0, "no heartbeats at 5s interval");
    assert!(stats.messages > stats.heartbeats);
}

//! Parity pin: the rust-native codecs in `compress/` must match the
//! AOT-lowered L1 Pallas kernels executed through PJRT, elementwise, for
//! every task's parameter size — so the native simulator and the XLA
//! three-layer path can never drift apart.
//!
//! Requires `make artifacts`; every test skips cleanly when missing.

use caesar_fl::compress::{caesar_compress, caesar_recover, quantize_stochastic, topk_sparsify};
use caesar_fl::runtime::{lit_f32, lit_scalar, to_scalar_f32, to_vec_f32, Runtime};
use caesar_fl::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    Runtime::open(&Runtime::default_dir()).ok()
}

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

const TASKS: [&str; 4] = ["cifar", "har", "speech", "oppo"];
const RATIOS: [f64; 4] = [0.0, 0.1, 0.35, 0.6];

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * x.abs(),
            "{what}: elem {i}: native {x} vs xla {y}"
        );
    }
}

#[test]
fn caesar_compress_kernel_matches_native() {
    let Some(rt) = runtime() else { return };
    for task in TASKS {
        let p = rt.manifest().task(task).unwrap().n_params;
        let w = randn(p, 0xC0);
        for &ratio in &RATIOS {
            let cm = caesar_compress(&w, ratio);
            let out = rt
                .exec(
                    &format!("compress_{task}"),
                    &[lit_f32(&w, &[p as i64]).unwrap(), lit_scalar(ratio as f32)],
                )
                .unwrap();
            let kept = to_vec_f32(&out[0]).unwrap();
            let mask = to_vec_f32(&out[1]).unwrap();
            let sign = to_vec_f32(&out[2]).unwrap();
            let avg = to_scalar_f32(&out[3]).unwrap();
            let max = to_scalar_f32(&out[4]).unwrap();
            assert_close(&cm.kept, &kept, 1e-6, &format!("{task} θ={ratio} kept"));
            for i in 0..p {
                assert_eq!(
                    cm.mask[i],
                    mask[i] > 0.5,
                    "{task} θ={ratio} mask at {i}"
                );
                if cm.mask[i] {
                    assert_eq!(cm.sign[i] as f32, sign[i], "{task} θ={ratio} sign at {i}");
                }
            }
            assert!((cm.avg_abs - avg).abs() < 1e-5, "{task} θ={ratio} avg");
            assert!((cm.max_abs - max).abs() < 1e-6, "{task} θ={ratio} max");
        }
    }
}

#[test]
fn caesar_recover_kernel_matches_native() {
    let Some(rt) = runtime() else { return };
    for task in TASKS {
        let p = rt.manifest().task(task).unwrap().n_params;
        let w = randn(p, 0xC1);
        // drifted local model: some sign flips, some magnitude overflows
        let mut rng = Rng::new(0xC2);
        let local: Vec<f32> = w.iter().map(|&x| x + 0.3 * rng.normal() as f32).collect();
        for &ratio in &RATIOS {
            let cm = caesar_compress(&w, ratio);
            let native = caesar_recover(&cm, &local);
            let mask_f: Vec<f32> =
                cm.mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect();
            let sign_f: Vec<f32> = cm.sign.iter().map(|&s| s as f32).collect();
            let out = rt
                .exec(
                    &format!("recover_{task}"),
                    &[
                        lit_f32(&cm.kept, &[p as i64]).unwrap(),
                        lit_f32(&mask_f, &[p as i64]).unwrap(),
                        lit_f32(&sign_f, &[p as i64]).unwrap(),
                        lit_scalar(cm.avg_abs),
                        lit_scalar(cm.max_abs),
                        lit_f32(&local, &[p as i64]).unwrap(),
                    ],
                )
                .unwrap();
            let xla = to_vec_f32(&out[0]).unwrap();
            assert_close(&native, &xla, 1e-6, &format!("{task} θ={ratio} recover"));
        }
    }
}

#[test]
fn topk_kernel_matches_native() {
    let Some(rt) = runtime() else { return };
    for task in TASKS {
        let p = rt.manifest().task(task).unwrap().n_params;
        let g = randn(p, 0xC3);
        for &ratio in &RATIOS {
            let native = topk_sparsify(&g, ratio);
            let out = rt
                .exec(
                    &format!("topk_{task}"),
                    &[lit_f32(&g, &[p as i64]).unwrap(), lit_scalar(ratio as f32)],
                )
                .unwrap();
            let xla = to_vec_f32(&out[0]).unwrap();
            assert_close(&native.dense, &xla, 1e-6, &format!("{task} θ={ratio} topk"));
        }
    }
}

#[test]
fn quantize_kernel_matches_native() {
    let Some(rt) = runtime() else { return };
    for task in TASKS {
        let p = rt.manifest().task(task).unwrap().n_params;
        let x = randn(p, 0xC4);
        let noise: Vec<f32> = {
            let mut rng = Rng::new(0xC5);
            (0..p).map(|_| rng.f32()).collect()
        };
        for levels in [3u32, 15, 255] {
            let native = quantize_stochastic(&x, levels, &noise);
            let out = rt
                .exec(
                    &format!("quantize_{task}"),
                    &[
                        lit_f32(&x, &[p as i64]).unwrap(),
                        lit_scalar(levels as f32),
                        lit_f32(&noise, &[p as i64]).unwrap(),
                    ],
                )
                .unwrap();
            let xla = to_vec_f32(&out[0]).unwrap();
            assert_close(&native, &xla, 1e-5, &format!("{task} s={levels} quantize"));
        }
    }
}

#[test]
fn codec_engine_backends_agree_end_to_end() {
    use caesar_fl::config::CompressionBackend;
    use caesar_fl::coordinator::CodecEngine;
    use caesar_fl::schemes::{DownloadCodec, UploadCodec};
    let Some(rt) = runtime() else { return };
    let task = "har";
    let p = rt.manifest().task(task).unwrap().n_params;
    let w = randn(p, 0xC6);
    let local: Vec<f32> = {
        let mut rng = Rng::new(0xC7);
        w.iter().map(|&x| x + 0.1 * rng.normal() as f32).collect()
    };
    let native = CodecEngine::native();
    let xla = CodecEngine::new(CompressionBackend::Xla, Some(&rt), task).unwrap();
    for codec in [
        DownloadCodec::Full,
        DownloadCodec::CaesarSplit { ratio: 0.35 },
        DownloadCodec::TopK { ratio: 0.35 },
    ] {
        let a = native.download(codec, &w, Some(&local), &mut Rng::new(9)).unwrap();
        let b = xla.download(codec, &w, Some(&local), &mut Rng::new(9)).unwrap();
        assert_close(&a.model, &b.model, 1e-6, &format!("download {codec:?}"));
        assert_eq!(a.wire_bits, b.wire_bits, "download bits {codec:?}");
    }
    let g = randn(p, 0xC8);
    for codec in [UploadCodec::Full, UploadCodec::TopK { ratio: 0.6 }, UploadCodec::Quant { bits: 4 }] {
        let a = native.upload(codec, &g, &mut Rng::new(11)).unwrap();
        let b = xla.upload(codec, &g, &mut Rng::new(11)).unwrap();
        assert_close(&a.grad, &b.grad, 1e-5, &format!("upload {codec:?}"));
    }
}

//! End-to-end smoke over the REAL three-layer stack: every scheme drives
//! the XLA trainer + (where configured) XLA codecs for a couple of
//! rounds, and a longer Caesar-vs-FedAvg run checks the paper's headline
//! ordering (less traffic at equal-or-better accuracy).
//!
//! Requires `make artifacts`; skips cleanly when missing.

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::runtime::Runtime;
use caesar_fl::schemes;

fn artifacts_available() -> bool {
    Runtime::open(&Runtime::default_dir()).is_ok()
}

fn xla_cfg(task: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(task);
    cfg.trainer = TrainerBackend::Xla;
    cfg.rounds = rounds;
    cfg.n_train = 1500;
    cfg.n_test = 300;
    cfg.tau = 5;
    cfg
}

#[test]
fn every_scheme_runs_on_the_xla_stack() {
    if !artifacts_available() {
        return;
    }
    for s in [
        "fedavg", "flexcom", "prowd", "pyramidfl", "caesar", "caesar-br", "caesar-dc",
    ] {
        let mut srv = Server::new(xla_cfg("har", 2), schemes::by_name(s).unwrap()).unwrap();
        let r = srv.run().unwrap();
        assert_eq!(r.records.len(), 2, "{s}");
        assert!(r.total_traffic_gb() > 0.0, "{s}");
        assert!(r.final_metric(false) > 0.0, "{s}");
    }
}

#[test]
fn xla_compression_backend_runs_caesar() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = xla_cfg("har", 3);
    cfg.compression = CompressionBackend::Xla;
    let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
    let r = srv.run().unwrap();
    assert_eq!(r.records.len(), 3);
}

#[test]
fn all_four_tasks_run_on_xla() {
    if !artifacts_available() {
        return;
    }
    for task in ["cifar", "har", "speech", "oppo"] {
        let mut srv =
            Server::new(xla_cfg(task, 2), schemes::by_name("caesar").unwrap()).unwrap();
        let r = srv.run().unwrap();
        assert_eq!(r.records.len(), 2, "{task}");
    }
}

#[test]
fn caesar_beats_fedavg_on_traffic_at_equal_rounds_xla() {
    if !artifacts_available() {
        return;
    }
    let run = |s: &str| {
        let mut cfg = xla_cfg("har", 10);
        cfg.alpha = 0.2;
        let mut srv = Server::new(cfg, schemes::by_name(s).unwrap()).unwrap();
        srv.run().unwrap()
    };
    let caesar = run("caesar");
    let fedavg = run("fedavg");
    assert!(
        caesar.total_traffic_gb() < 0.85 * fedavg.total_traffic_gb(),
        "caesar {} GB vs fedavg {} GB",
        caesar.total_traffic_gb(),
        fedavg.total_traffic_gb()
    );
    assert!(
        caesar.mean_wait_s() < fedavg.mean_wait_s(),
        "caesar wait {} vs fedavg {}",
        caesar.mean_wait_s(),
        fedavg.mean_wait_s()
    );
}

#[test]
fn xla_and_native_trainers_converge_similarly() {
    if !artifacts_available() {
        return;
    }
    let run = |backend: TrainerBackend| {
        let mut cfg = xla_cfg("har", 12);
        cfg.trainer = backend;
        cfg.alpha = 0.3;
        let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
        srv.run().unwrap().final_metric(false)
    };
    let xla = run(TrainerBackend::Xla);
    let native = run(TrainerBackend::Native);
    assert!(
        (xla - native).abs() < 0.15,
        "backends diverged: xla {xla} vs native {native}"
    );
}

//! Wire-format benchmark: payload encode/decode throughput per codec,
//! in-place (`recover_download_into`) vs materializing recovery with
//! allocation traffic per call, and sparse-payload vs densified
//! aggregation at fleet scale (100 / 1k / 10k devices' uploads folded
//! into one round's shards).
//!
//! Results are written to BENCH_wire.json in the current directory with
//! `"placeholder": false` (the flag marks hand-authored files committed
//! from toolchain-less environments; this binary always measures).
//! Quick mode: CAESAR_BENCH_QUICK=1 (shorter cases, skips the 10k scale).

use std::time::Instant;

use caesar_fl::bench::Bench;
use caesar_fl::compress::{quant, topk};
use caesar_fl::coordinator::CodecEngine;
use caesar_fl::engine::AggregatorShard;
use caesar_fl::schemes::DownloadCodec;
use caesar_fl::util::alloc_count::{self, CountingAlloc};
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::rng::Rng;
use caesar_fl::wire::Payload;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn payloads_for(n: usize, seed: u64) -> Vec<(&'static str, Payload)> {
    let x = randn(n, seed);
    let noise: Vec<f32> = {
        let mut rng = Rng::new(seed ^ 0xA0);
        (0..n).map(|_| rng.f32()).collect()
    };
    let levels = quant::levels_for_bits(4);
    let (norm, codes) = quant::quantize_codes(&x, levels, Some(&noise));
    vec![
        ("dense", Payload::Dense(x.clone())),
        ("topk θ=0.9", topk::topk_encode(&x, 0.9).0),
        (
            "caesar θ=0.35",
            Payload::CaesarSplit(caesar_fl::compress::caesar_compress(&x, 0.35)),
        ),
        ("quant 4b", Payload::Quant { bits: 4, levels, norm, codes }),
    ]
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let n_params = if quick { 16_384 } else { 131_072 };
    let mut rows: Vec<Json> = Vec::new();

    // --- encode / decode throughput per codec ---
    let b = Bench::new(&format!("payload encode (P={n_params})")).quick();
    for (name, p) in payloads_for(n_params, 1) {
        let r = b.case(name, n_params, || {
            std::hint::black_box(std::hint::black_box(&p).encode());
        });
        let mut o = Json::obj();
        o.set("case", json::s(&r.name)).set("mean_ns", json::num(r.mean_ns));
        rows.push(o);
    }
    let b = Bench::new(&format!("payload decode (P={n_params})")).quick();
    for (name, p) in payloads_for(n_params, 2) {
        let enc = p.encode();
        let r = b.case(name, n_params, || {
            std::hint::black_box(std::hint::black_box(&enc).decode());
        });
        let mut o = Json::obj();
        o.set("case", json::s(&r.name)).set("mean_ns", json::num(r.mean_ns));
        rows.push(o);
    }

    // --- materializing vs in-place download recovery ---
    // recover_download allocates the decoded payload AND the recovered
    // model per call; recover_download_into streams off the bytes into a
    // reused buffer. Alloc traffic is measured around the timed loop.
    println!("\n== bench: recovery (P={n_params}) ==");
    println!(
        "{:>14}  {:>14}  {:>14}  {:>14}  {:>14}",
        "codec", "alloc ms", "into ms", "alloc B/call", "into B/call"
    );
    let e = CodecEngine::native();
    let w = randn(n_params, 3);
    let local = randn(n_params, 4);
    let reps = if quick { 50 } else { 200 };
    let mut rec_rows: Vec<Json> = Vec::new();
    for (name, codec) in [
        ("full", DownloadCodec::Full),
        ("topk θ=0.9", DownloadCodec::TopK { ratio: 0.9 }),
        ("caesar θ=0.35", DownloadCodec::CaesarSplit { ratio: 0.35 }),
        ("quant 4b", DownloadCodec::Quant { bits: 4 }),
    ] {
        let enc = e.encode_download(codec, &w, &mut Rng::new(5)).unwrap();
        let time_and_alloc = |into: bool| -> (f64, f64) {
            let mut out = Vec::new();
            let a0 = alloc_count::snapshot();
            let t0 = Instant::now();
            for _ in 0..reps {
                if into {
                    e.recover_download_into(&enc, Some(&local), &mut out).unwrap();
                    std::hint::black_box(&out);
                } else {
                    std::hint::black_box(e.recover_download(&enc, Some(&local)).unwrap());
                }
            }
            let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
            let bytes = alloc_count::snapshot().since(&a0).bytes as f64 / reps as f64;
            (ms, bytes)
        };
        let (alloc_ms, alloc_bytes) = time_and_alloc(false);
        let (into_ms, into_bytes) = time_and_alloc(true);
        println!(
            "{name:>14}  {alloc_ms:>14.3}  {into_ms:>14.3}  {alloc_bytes:>14.0}  {into_bytes:>14.0}"
        );
        let mut o = Json::obj();
        o.set("codec", json::s(name))
            .set("recover_ms", json::num(alloc_ms))
            .set("recover_into_ms", json::num(into_ms))
            .set("recover_alloc_bytes_per_call", json::num(alloc_bytes))
            .set("recover_into_alloc_bytes_per_call", json::num(into_bytes));
        rec_rows.push(o);
    }

    // --- sparse vs dense aggregation of one round's uploads ---
    // α = 0.1 participants, Top-K θ=0.9 uploads: the sparse path folds
    // O(kept) per device straight off the serialized bytes instead of
    // densifying to O(n).
    let scales: &[usize] = if quick { &[100, 1_000] } else { &[100, 1_000, 10_000] };
    println!("\n== bench: sparse vs dense aggregation (P={n_params}, θ=0.9) ==");
    println!(
        "{:>8}  {:>12}  {:>14}  {:>14}  {:>8}",
        "devices", "participants", "dense ms", "sparse ms", "speedup"
    );
    let mut agg_rows: Vec<Json> = Vec::new();
    for &devices in scales {
        let participants = (devices / 10).max(1);
        let encoded: Vec<caesar_fl::wire::EncodedPayload> = (0..participants)
            .map(|d| topk::topk_encode(&randn(n_params, 0xB0 + d as u64), 0.9).0.encode())
            .collect();
        let expect: Vec<usize> = (0..participants).collect();
        let reps = if quick { 2 } else { 5 };
        let time_ms = |sparse: bool| -> f64 {
            let t0 = Instant::now();
            for _ in 0..reps {
                let mut shard = AggregatorShard::new(0, n_params, expect.clone());
                for (d, enc) in encoded.iter().enumerate() {
                    if sparse {
                        shard.fold_encoded(d, enc, 1.0);
                    } else {
                        shard.fold(d, &enc.decode().to_dense(), 1.0);
                    }
                }
                std::hint::black_box(&shard);
            }
            t0.elapsed().as_secs_f64() * 1e3 / reps as f64
        };
        let dense_ms = time_ms(false);
        let sparse_ms = time_ms(true);
        println!(
            "{devices:>8}  {participants:>12}  {dense_ms:>14.2}  {sparse_ms:>14.2}  {:>7.2}x",
            dense_ms / sparse_ms
        );
        let mut o = Json::obj();
        o.set("devices", json::num(devices as f64))
            .set("participants", json::num(participants as f64))
            .set("dense_ms", json::num(dense_ms))
            .set("sparse_ms", json::num(sparse_ms))
            .set("speedup", json::num(dense_ms / sparse_ms));
        agg_rows.push(o);
    }

    let mut out = Json::obj();
    out.set("bench", json::s("wire"))
        .set("n_params", json::num(n_params as f64))
        .set("quick", Json::Bool(quick))
        .set("placeholder", Json::Bool(false))
        .set("codec_cases", Json::Arr(rows))
        .set("recovery", Json::Arr(rec_rows))
        .set("aggregation", Json::Arr(agg_rows));
    std::fs::write("BENCH_wire.json", out.to_string()).expect("write BENCH_wire.json");
    println!("wrote BENCH_wire.json");
}

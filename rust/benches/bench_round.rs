//! End-to-end round benchmark — one full communication round (plan →
//! download codec → local SGD → upload codec → aggregate) per scheme.
//! This is the cost row behind Table 3 / Fig 5: everything the
//! coordinator executes per round, on both trainer backends.

use caesar_fl::bench::Bench;
use caesar_fl::config::{ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::runtime::Runtime;
use caesar_fl::schemes;

fn cfg(task: &str, backend: TrainerBackend) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(task);
    cfg.trainer = backend;
    cfg.n_train = 4000;
    cfg.n_test = 800;
    cfg.eval_every = usize::MAX; // benchmarked separately
    cfg
}

fn bench_backend(label: &str, backend: TrainerBackend) {
    let b = Bench::new(&format!("full round, har ({label} trainer)")).quick();
    for scheme in ["fedavg", "flexcom", "prowd", "pyramidfl", "caesar"] {
        let mut srv =
            Server::new(cfg("har", backend), schemes::by_name(scheme).unwrap()).unwrap();
        let mut t = 0usize;
        b.case(scheme, 0, || {
            t += 1;
            srv.step(t).unwrap();
        });
    }
}

fn main() {
    bench_backend("native", TrainerBackend::Native);
    if Runtime::open(&Runtime::default_dir()).is_ok() {
        bench_backend("xla", TrainerBackend::Xla);
    } else {
        eprintln!("skipping XLA rounds: artifacts missing (run `make artifacts`)");
    }

    // evaluation cost (amortized every eval_every rounds)
    let b = Bench::new("global eval").quick();
    for (label, backend) in [("native", TrainerBackend::Native), ("xla", TrainerBackend::Xla)] {
        if backend == TrainerBackend::Xla && Runtime::open(&Runtime::default_dir()).is_err() {
            continue;
        }
        let srv = Server::new(cfg("har", backend), schemes::by_name("caesar").unwrap()).unwrap();
        b.case(&format!("{label} n_test=800"), 800, || {
            srv.evaluate().unwrap();
        });
    }
}

//! PJRT runtime benchmark: latency of the AOT HLO executables the
//! coordinator drives on the hot path — train-step chunks per batch
//! bucket, eval chunks, and the L1 compression kernels.
//!
//! Requires `make artifacts`. Skips (exit 0) when artifacts are missing.

use caesar_fl::bench::Bench;
use caesar_fl::runtime::{lit_f32, lit_i32, lit_scalar, Runtime};
use caesar_fl::util::rng::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    let rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_runtime: {e} (run `make artifacts`)");
            return Ok(());
        }
    };
    let m = rt.manifest();
    let task = "cifar";
    let spec = m.task(task).unwrap().clone();
    let (p, d, chunk) = (spec.n_params, spec.d_in, m.chunk);
    let w = randn(p, 1);

    let b = Bench::new("train chunk (cifar, τ-chunk per call)").quick();
    for bucket in m.train_buckets(task) {
        let xs = randn(chunk * bucket * d, 2);
        let ys: Vec<i32> = {
            let mut rng = Rng::new(3);
            (0..chunk * bucket).map(|_| rng.below(10) as i32).collect()
        };
        let module = format!("train_{task}_b{bucket}");
        b.case(&format!("b={bucket}"), chunk * bucket, || {
            rt.exec(
                &module,
                &[
                    lit_f32(&w, &[p as i64]).unwrap(),
                    lit_f32(&xs, &[chunk as i64, bucket as i64, d as i64]).unwrap(),
                    lit_i32(&ys, &[chunk as i64, bucket as i64]).unwrap(),
                    lit_scalar(0.1),
                ],
            )
            .unwrap();
        });
    }

    let b = Bench::new("eval chunk (cifar)").quick();
    let e = m.eval_chunk;
    let xs = randn(e * d, 4);
    b.case(&format!("batch={e}"), e, || {
        rt.exec(
            &format!("eval_{task}"),
            &[lit_f32(&w, &[p as i64]).unwrap(), lit_f32(&xs, &[e as i64, d as i64]).unwrap()],
        )
        .unwrap();
    });

    let b = Bench::new("L1 kernels via PJRT (cifar)").quick();
    b.case("compress θ=0.35", p, || {
        rt.exec(
            &format!("compress_{task}"),
            &[lit_f32(&w, &[p as i64]).unwrap(), lit_scalar(0.35)],
        )
        .unwrap();
    });
    b.case("topk θ=0.6", p, || {
        rt.exec(
            &format!("topk_{task}"),
            &[lit_f32(&w, &[p as i64]).unwrap(), lit_scalar(0.6)],
        )
        .unwrap();
    });
    Ok(())
}

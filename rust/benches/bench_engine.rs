//! Sequential vs parallel round-engine benchmark at fleet scale, with
//! allocation traffic and download-encode work as first-class metrics.
//!
//! Runs full communication rounds (plan → download codec → local SGD →
//! upload codec → sharded aggregation) on the HAR stand-in with the fleet
//! scaled to 100 / 1 000 / 10 000 simulated devices (α = 0.1 → 10 / 100 /
//! 1 000 participants per round), once with `engine.workers = 1` (the
//! sequential baseline) and once with one worker per host core. The two
//! paths produce bit-identical models (pinned by tests/engine_parity.rs),
//! so the speedup is free.
//!
//! Per case this reports, alongside ms/round:
//! * `alloc_bytes_per_round` / `allocs_per_round` — allocation traffic
//!   measured by a counting global allocator (the hot path is supposed to
//!   be reuse-dominated: encode cache, pooled scratch, in-place recovery);
//! * `encode_calls_per_round` vs `encode_requests_per_round` — downloads
//!   served vs `encode_download` executions. With the per-round encode
//!   cache, calls scale with DISTINCT codecs, not participants; the
//!   dedicated `encode_cache` case pins the acceptance target (100
//!   participants sharing ≤ 4 distinct codecs → ≥ 25× fewer encodes).
//!
//! Two persistent-pool cases ride along: `pool` asserts trainer builds
//! are O(workers) per RUN (≥ R× fewer than the legacy per-round fan-out
//! over R rounds), and `cross_round_cache` records the generation-keyed
//! encode reuse across rounds whose model never moved.
//!
//! Results are written to BENCH_engine.json in the current directory.
//! Quick mode: CAESAR_BENCH_QUICK=1 (fewer rounds, skips the 10k scale).

use std::time::Instant;

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::fleet::FleetKind;
use caesar_fl::schemes;
use caesar_fl::util::alloc_count::{self, CountingAlloc};
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::threadpool::workers;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// One timed configuration: host time, allocation traffic and download
/// encode counts, all per round.
struct Measured {
    ms: f64,
    alloc_bytes: f64,
    allocs: f64,
    encode_requests: f64,
    encode_calls: f64,
}

struct Case {
    devices: usize,
    participants: usize,
    seq: Measured,
    par: Measured,
    par_workers: usize,
}

fn cfg_at(devices: usize, engine_workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.fleet = FleetKind::JetsonScaled(devices);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    // enough data that every device holds a shard even at 10k devices
    cfg.n_train = (4 * devices).max(8_000);
    cfg.n_test = 200;
    cfg.tau = 5;
    cfg.eval_every = usize::MAX; // eval is benchmarked elsewhere
    cfg.engine.workers = engine_workers;
    cfg
}

/// Mean per-round host milliseconds, allocation traffic and encode counts
/// over `rounds` timed rounds (after one warm-up round).
fn measure(cfg: ExperimentConfig, scheme: &str, rounds: usize) -> Measured {
    let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
    srv.step(1).unwrap(); // warm-up: first-touch allocations, locals fill
    let stats0 = srv.engine().stats();
    let alloc0 = alloc_count::snapshot();
    let t0 = Instant::now();
    for t in 2..2 + rounds {
        srv.step(t).unwrap();
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
    let alloc = alloc_count::snapshot().since(&alloc0);
    let stats = srv.engine().stats();
    let per = |x: usize, y: usize| (x - y) as f64 / rounds as f64;
    Measured {
        ms,
        alloc_bytes: alloc.bytes as f64 / rounds as f64,
        allocs: alloc.count as f64 / rounds as f64,
        encode_requests: per(stats.download_requests, stats0.download_requests),
        encode_calls: per(stats.download_encodes, stats0.download_encodes),
    }
}

fn measured_json(m: &Measured) -> Vec<(&'static str, Json)> {
    vec![
        ("ms_per_round", json::num(m.ms)),
        ("alloc_bytes_per_round", json::num(m.alloc_bytes)),
        ("allocs_per_round", json::num(m.allocs)),
        ("encode_requests_per_round", json::num(m.encode_requests)),
        ("encode_calls_per_round", json::num(m.encode_calls)),
    ]
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let par_workers = workers(usize::MAX);
    let scales: &[usize] = if quick { &[100, 1_000] } else { &[100, 1_000, 10_000] };
    let rounds = |devices: usize| -> usize {
        match (quick, devices) {
            (true, _) => 2,
            (false, d) if d >= 10_000 => 3,
            _ => 5,
        }
    };

    println!("== bench: engine (sequential vs {par_workers} workers) ==");
    println!(
        "{:>8}  {:>12}  {:>12}  {:>12}  {:>8}  {:>14}  {:>12}",
        "devices", "participants", "seq ms/round", "par ms/round", "speedup", "seq MB/round", "enc/round"
    );
    let mut cases = Vec::new();
    for &n in scales {
        let r = rounds(n);
        let seq = measure(cfg_at(n, 1), "caesar", r);
        let par = measure(cfg_at(n, par_workers), "caesar", r);
        let participants = cfg_at(n, 1).participants_per_round();
        println!(
            "{n:>8}  {participants:>12}  {:>12.1}  {:>12.1}  {:>7.2}x  {:>14.2}  {:>12.1}",
            seq.ms,
            par.ms,
            seq.ms / par.ms,
            seq.alloc_bytes / (1024.0 * 1024.0),
            seq.encode_calls,
        );
        cases.push(Case { devices: n, participants, seq, par, par_workers });
    }

    // --- encode-cache acceptance case (ISSUE 3): 1000 devices → 100
    // participants per round, staleness clustering pinned to 3 → at most
    // 4 distinct download codecs (3 CaesarSplit ratios + Full for
    // first-timers). Target: encodes drop ≥ 25× vs per-device encoding.
    let cache_rounds = if quick { 3 } else { 6 };
    let mut cache_cfg = cfg_at(1_000, 1);
    cache_cfg.clusters = 3;
    let m = measure(cache_cfg, "caesar", cache_rounds);
    let reduction = if m.encode_calls > 0.0 { m.encode_requests / m.encode_calls } else { 0.0 };
    println!(
        "\n== bench: encode cache (1000 devices, clusters=3) ==\n\
         {:>12.1} downloads/round  {:>8.1} encodes/round  {:>7.1}x reduction",
        m.encode_requests, m.encode_calls, reduction
    );

    // --- persistent-pool acceptance case (ISSUE 4): trainer builds are
    // O(workers) per RUN. The pre-pool engine built one trainer per worker
    // per ROUND, so over R rounds at W workers the persistent pool must
    // show >= R× fewer builds (builds <= W vs the legacy R·W).
    let pool_rounds = if quick { 4 } else { 10 };
    let pool_cfg = cfg_at(1_000, 4);
    let mut pool_srv = Server::new(pool_cfg, schemes::by_name("caesar").unwrap()).unwrap();
    for t in 1..=pool_rounds {
        pool_srv.step(t).unwrap();
    }
    let pst = pool_srv.engine().stats();
    let pool_workers_used = workers(4);
    let trainer_builds = pst.trainer_builds;
    assert!(trainer_builds >= 1, "stats must report the executor's trainer builds");
    let legacy_builds = pool_rounds * pool_workers_used;
    let builds_reduction = legacy_builds as f64 / trainer_builds as f64;
    println!(
        "\n== bench: persistent pool ({pool_rounds} rounds, {pool_workers_used} workers) ==\n\
         {trainer_builds:>8} trainer builds  (legacy {legacy_builds})  {builds_reduction:>6.1}x fewer"
    );
    assert!(
        builds_reduction >= pool_rounds as f64,
        "persistent pool must amortize trainer builds: {trainer_builds} builds \
         over {pool_rounds} rounds at {pool_workers_used} workers"
    );

    // --- cross-round cache case: rounds whose participants all drop out
    // never move the model, so later rounds are served from carried
    // encodes (generation key = model version).
    let cross_rounds = 3usize;
    let mut cross_cfg = cfg_at(1_000, 1);
    cross_cfg.engine.dropout_rate = 1.0;
    let mut cross_srv = Server::new(cross_cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    for t in 1..=cross_rounds {
        cross_srv.step(t).unwrap();
    }
    let cst = cross_srv.engine().stats();
    println!(
        "\n== bench: cross-round cache ({cross_rounds} all-dropout rounds) ==\n\
         {:>8} downloads  {:>4} encodes  {:>6} cross-round hits",
        cst.download_requests, cst.download_encodes, cst.cache_cross_round_hits
    );

    let mut out = Json::obj();
    out.set("bench", json::s("engine_round"))
        .set("task", json::s("har"))
        .set("trainer", json::s("native"))
        .set("quick", Json::Bool(quick))
        // this binary always measures; `true` marks hand-authored files
        // committed from environments without a toolchain
        .set("placeholder", Json::Bool(false))
        .set("host_workers", json::num(par_workers as f64));
    let rows: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("devices", json::num(c.devices as f64))
                .set("participants", json::num(c.participants as f64))
                .set("workers", json::num(c.par_workers as f64))
                .set("speedup", json::num(c.seq.ms / c.par.ms));
            // seq_/par_ prefixes expand to seq_ms_per_round etc.
            for (k, v) in measured_json(&c.seq) {
                o.set(&format!("seq_{k}"), v);
            }
            for (k, v) in measured_json(&c.par) {
                o.set(&format!("par_{k}"), v);
            }
            o
        })
        .collect();
    out.set("cases", Json::Arr(rows));
    let mut cache_row = Json::obj();
    cache_row
        .set("devices", json::num(1_000.0))
        .set("participants", json::num(100.0))
        .set("clusters", json::num(3.0))
        .set("encode_requests_per_round", json::num(m.encode_requests))
        .set("encode_calls_per_round", json::num(m.encode_calls))
        .set("encode_reduction", json::num(reduction))
        .set("alloc_bytes_per_round", json::num(m.alloc_bytes));
    out.set("encode_cache", cache_row);
    let mut pool_row = Json::obj();
    pool_row
        .set("rounds", json::num(pool_rounds as f64))
        .set("workers", json::num(pool_workers_used as f64))
        .set("trainer_builds", json::num(trainer_builds as f64))
        .set("builds_reduction", json::num(builds_reduction));
    out.set("pool", pool_row);
    let mut cross_row = Json::obj();
    cross_row
        .set("rounds", json::num(cross_rounds as f64))
        .set("dropout", json::num(1.0))
        .set("download_requests", json::num(cst.download_requests as f64))
        .set("download_encodes", json::num(cst.download_encodes as f64))
        .set("cache_cross_round_hits", json::num(cst.cache_cross_round_hits as f64));
    out.set("cross_round_cache", cross_row);
    std::fs::write("BENCH_engine.json", out.to_string()).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}

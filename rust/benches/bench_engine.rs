//! Sequential vs parallel round-engine benchmark at fleet scale, with
//! allocation traffic and download-encode work as first-class metrics.
//!
//! Runs full communication rounds (plan → download codec → local SGD →
//! upload codec → sharded aggregation) on the HAR stand-in with the fleet
//! scaled to 100 / 1 000 / 10 000 simulated devices (α = 0.1 → 10 / 100 /
//! 1 000 participants per round), once with `engine.workers = 1` (the
//! sequential baseline) and once with one worker per host core. The two
//! paths produce bit-identical models (pinned by tests/engine_parity.rs),
//! so the speedup is free.
//!
//! Per case this reports, alongside ms/round:
//! * `alloc_bytes_per_round` / `allocs_per_round` — allocation traffic
//!   measured by a counting global allocator (the hot path is supposed to
//!   be reuse-dominated: encode cache, pooled scratch, in-place recovery);
//! * `encode_calls_per_round` vs `encode_requests_per_round` — downloads
//!   served vs `encode_download` executions. With the per-round encode
//!   cache, calls scale with DISTINCT codecs, not participants; the
//!   dedicated `encode_cache` case pins the acceptance target (100
//!   participants sharing ≤ 4 distinct codecs → ≥ 25× fewer encodes).
//!
//! Two persistent-pool cases ride along: `pool` asserts trainer builds
//! are O(workers) per RUN (≥ R× fewer than the legacy per-round fan-out
//! over R rounds), and `cross_round_cache` records the generation-keyed
//! encode reuse across rounds whose model never moved.
//!
//! Two hot-path cases cover the million-scale selection/aggregation work:
//! `selection_scale` races the O(n) radix threshold select against the
//! old sort-order `select_nth_unstable` across key counts (asserting
//! bit-identical thresholds and a zero-allocation warm path, recording
//! the knee where radix overtakes), and `tree_agg` times the fixed-shape
//! tree reduction (streaming vs parallel pairwise — asserted
//! bit-identical) against a flat left-fold reference, reporting
//! reduce-phase allocation and live-bytes peak via the counting
//! allocator and asserting chunk-sharded buffers stay below chunk size.
//!
//! A `semi_async` case compares the barrier schedule (depth 1, bound 0)
//! against the pipelined one (depth 2, bound 2) at 1 000 devices: same
//! seed, same participants — the overlap path closes each round on its
//! on-time cohort and folds the straggler tail later, so its mean
//! simulated round time must be no longer (and with real stragglers,
//! materially shorter) than the barrier's.
//!
//! Results are written to BENCH_engine.json in the current directory.
//! Quick mode: CAESAR_BENCH_QUICK=1 (fewer rounds, skips the 10k scale).

use std::time::Instant;

use caesar_fl::compress::{abs_sort_keys, select_threshold};
use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::engine::{reduce_shards_parallel, AggregatorShard, ShardReducer};
use caesar_fl::fleet::FleetKind;
use caesar_fl::schemes;
use caesar_fl::util::alloc_count::{self, CountingAlloc};
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::rng::Rng;
use caesar_fl::util::threadpool::workers;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// One timed configuration: host time, allocation traffic and download
/// encode counts, all per round.
struct Measured {
    ms: f64,
    alloc_bytes: f64,
    allocs: f64,
    encode_requests: f64,
    encode_calls: f64,
}

struct Case {
    devices: usize,
    participants: usize,
    seq: Measured,
    par: Measured,
    par_workers: usize,
}

fn cfg_at(devices: usize, engine_workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.fleet = FleetKind::JetsonScaled(devices);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    // enough data that every device holds a shard even at 10k devices
    cfg.n_train = (4 * devices).max(8_000);
    cfg.n_test = 200;
    cfg.tau = 5;
    cfg.eval_every = usize::MAX; // eval is benchmarked elsewhere
    cfg.engine.workers = engine_workers;
    cfg
}

/// Mean per-round host milliseconds, allocation traffic and encode counts
/// over `rounds` timed rounds (after one warm-up round).
fn measure(cfg: ExperimentConfig, scheme: &str, rounds: usize) -> Measured {
    let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
    srv.step(1).unwrap(); // warm-up: first-touch allocations, locals fill
    let stats0 = srv.engine().stats();
    let alloc0 = alloc_count::snapshot();
    let t0 = Instant::now();
    for t in 2..2 + rounds {
        srv.step(t).unwrap();
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
    let alloc = alloc_count::snapshot().since(&alloc0);
    let stats = srv.engine().stats();
    let per = |x: usize, y: usize| (x - y) as f64 / rounds as f64;
    Measured {
        ms,
        alloc_bytes: alloc.bytes as f64 / rounds as f64,
        allocs: alloc.count as f64 / rounds as f64,
        encode_requests: per(stats.download_requests, stats0.download_requests),
        encode_calls: per(stats.download_encodes, stats0.download_encodes),
    }
}

fn measured_json(m: &Measured) -> Vec<(&'static str, Json)> {
    vec![
        ("ms_per_round", json::num(m.ms)),
        ("alloc_bytes_per_round", json::num(m.alloc_bytes)),
        ("allocs_per_round", json::num(m.allocs)),
        ("encode_requests_per_round", json::num(m.encode_requests)),
        ("encode_calls_per_round", json::num(m.encode_calls)),
    ]
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let par_workers = workers(usize::MAX);
    let scales: &[usize] = if quick { &[100, 1_000] } else { &[100, 1_000, 10_000] };
    let rounds = |devices: usize| -> usize {
        match (quick, devices) {
            (true, _) => 2,
            (false, d) if d >= 10_000 => 3,
            _ => 5,
        }
    };

    println!("== bench: engine (sequential vs {par_workers} workers) ==");
    println!(
        "{:>8}  {:>12}  {:>12}  {:>12}  {:>8}  {:>14}  {:>12}",
        "devices", "participants", "seq ms/round", "par ms/round", "speedup", "seq MB/round", "enc/round"
    );
    let mut cases = Vec::new();
    for &n in scales {
        let r = rounds(n);
        let seq = measure(cfg_at(n, 1), "caesar", r);
        let par = measure(cfg_at(n, par_workers), "caesar", r);
        let participants = cfg_at(n, 1).participants_per_round();
        println!(
            "{n:>8}  {participants:>12}  {:>12.1}  {:>12.1}  {:>7.2}x  {:>14.2}  {:>12.1}",
            seq.ms,
            par.ms,
            seq.ms / par.ms,
            seq.alloc_bytes / (1024.0 * 1024.0),
            seq.encode_calls,
        );
        cases.push(Case { devices: n, participants, seq, par, par_workers });
    }

    // --- encode-cache acceptance case (ISSUE 3): 1000 devices → 100
    // participants per round, staleness clustering pinned to 3 → at most
    // 4 distinct download codecs (3 CaesarSplit ratios + Full for
    // first-timers). Target: encodes drop ≥ 25× vs per-device encoding.
    let cache_rounds = if quick { 3 } else { 6 };
    let mut cache_cfg = cfg_at(1_000, 1);
    cache_cfg.clusters = 3;
    let m = measure(cache_cfg, "caesar", cache_rounds);
    let reduction = if m.encode_calls > 0.0 { m.encode_requests / m.encode_calls } else { 0.0 };
    println!(
        "\n== bench: encode cache (1000 devices, clusters=3) ==\n\
         {:>12.1} downloads/round  {:>8.1} encodes/round  {:>7.1}x reduction",
        m.encode_requests, m.encode_calls, reduction
    );

    // --- persistent-pool acceptance case (ISSUE 4): trainer builds are
    // O(workers) per RUN. The pre-pool engine built one trainer per worker
    // per ROUND, so over R rounds at W workers the persistent pool must
    // show >= R× fewer builds (builds <= W vs the legacy R·W).
    let pool_rounds = if quick { 4 } else { 10 };
    let pool_cfg = cfg_at(1_000, 4);
    let mut pool_srv = Server::new(pool_cfg, schemes::by_name("caesar").unwrap()).unwrap();
    for t in 1..=pool_rounds {
        pool_srv.step(t).unwrap();
    }
    let pst = pool_srv.engine().stats();
    let pool_workers_used = workers(4);
    let trainer_builds = pst.trainer_builds;
    assert!(trainer_builds >= 1, "stats must report the executor's trainer builds");
    let legacy_builds = pool_rounds * pool_workers_used;
    let builds_reduction = legacy_builds as f64 / trainer_builds as f64;
    println!(
        "\n== bench: persistent pool ({pool_rounds} rounds, {pool_workers_used} workers) ==\n\
         {trainer_builds:>8} trainer builds  (legacy {legacy_builds})  {builds_reduction:>6.1}x fewer"
    );
    assert!(
        builds_reduction >= pool_rounds as f64,
        "persistent pool must amortize trainer builds: {trainer_builds} builds \
         over {pool_rounds} rounds at {pool_workers_used} workers"
    );

    // --- cross-round cache case: rounds whose participants all drop out
    // never move the model, so later rounds are served from carried
    // encodes (generation key = model version).
    let cross_rounds = 3usize;
    let mut cross_cfg = cfg_at(1_000, 1);
    cross_cfg.engine.dropout_rate = 1.0;
    let mut cross_srv = Server::new(cross_cfg, schemes::by_name("fedavg").unwrap()).unwrap();
    for t in 1..=cross_rounds {
        cross_srv.step(t).unwrap();
    }
    let cst = cross_srv.engine().stats();
    println!(
        "\n== bench: cross-round cache ({cross_rounds} all-dropout rounds) ==\n\
         {:>8} downloads  {:>4} encodes  {:>6} cross-round hits",
        cst.download_requests, cst.download_encodes, cst.cache_cross_round_hits
    );

    // --- semi-async pipelined rounds (ISSUE 9): with the window open the
    // coordinator closes round t on its on-time cohort and folds the
    // straggler tail into a later round, so the simulated round time
    // drops from the slowest participant to the cost-median deadline.
    // Same seed → same participants and per-device costs on both paths,
    // so the overlap round can never be longer than the barrier round.
    let sa_rounds = if quick { 3 } else { 6 };
    let mut sa_run = |depth: usize, bound: usize| {
        let mut cfg = cfg_at(1_000, par_workers);
        cfg.rounds = sa_rounds;
        cfg.engine.pipeline_depth = depth;
        cfg.engine.staleness_bound = bound;
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        let t0 = Instant::now();
        let res = srv.run().unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3 / sa_rounds as f64;
        let round_s =
            res.records.iter().map(|r| r.round_s).sum::<f64>() / res.records.len().max(1) as f64;
        (ms, round_s)
    };
    let (barrier_ms, barrier_round_s) = sa_run(1, 0);
    let (overlap_ms, overlap_round_s) = sa_run(2, 2);
    let round_s_reduction =
        if overlap_round_s > 0.0 { barrier_round_s / overlap_round_s } else { 1.0 };
    assert!(
        overlap_round_s <= barrier_round_s + 1e-12,
        "overlap must never lengthen the simulated round: \
         {overlap_round_s} vs {barrier_round_s}"
    );
    println!(
        "\n== bench: semi-async rounds (1000 devices, depth 2, bound 2) ==\n\
         {barrier_round_s:>10.2} s/round barrier  {overlap_round_s:>8.2} s/round overlap  \
         {round_s_reduction:>6.2}x shorter\n\
         host: {barrier_ms:>8.1} ms/round barrier  {overlap_ms:>8.1} ms/round overlap"
    );

    // --- radix selection case (ISSUE 7): the per-participant Top-K /
    // quantile threshold comes from an O(n) MSB-first radix select over
    // the u32 abs-sort keys instead of a sort-order select_nth_unstable.
    // Both paths see identical keys: the thresholds must be bit-identical,
    // and the warm radix path must allocate nothing (pooled key buffer).
    let sel_sizes: &[usize] =
        if quick { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000, 1_000_000] };
    println!("\n== bench: threshold selection (radix vs select_nth_unstable) ==");
    println!("{:>10}  {:>14}  {:>14}  {:>8}", "keys", "sort ms/call", "radix ms/call", "speedup");
    let mut sel_rows: Vec<Json> = Vec::new();
    let mut knee: Option<usize> = None;
    let mut sel_rng = Rng::new(0x5E1E);
    for &n in sel_sizes {
        let g: Vec<f32> = (0..n).map(|_| sel_rng.normal() as f32).collect();
        let rank = ((n as f64 * 0.99) as usize).min(n - 1);
        let iters = (4_000_000 / n).clamp(4, 400);

        // sort-order baseline on the same keys, buffer reused like the
        // pre-radix hot path did
        let mut keys: Vec<u32> = Vec::new();
        abs_sort_keys(&g, &mut keys);
        let (_, kth, _) = keys.select_nth_unstable(rank);
        let sort_thr = f32::from_bits(*kth);
        let t0 = Instant::now();
        for _ in 0..iters {
            abs_sort_keys(&g, &mut keys);
            let (_, kth, _) = keys.select_nth_unstable(rank);
            std::hint::black_box(*kth);
        }
        let sort_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;

        // radix path: one warm-up call sizes the pooled buffer, then the
        // warm path must be allocation-free
        let radix_thr = select_threshold(&g, rank);
        assert_eq!(
            radix_thr.to_bits(),
            sort_thr.to_bits(),
            "radix select must match select_nth_unstable bit-for-bit at n={n}"
        );
        let a0 = alloc_count::snapshot();
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(select_threshold(std::hint::black_box(&g), rank));
        }
        let radix_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        let warm = alloc_count::snapshot().since(&a0);
        assert_eq!(
            warm.bytes, 0,
            "warm radix select must reuse the pooled key buffer \
             ({} bytes over {iters} calls at n={n})",
            warm.bytes
        );

        if knee.is_none() && radix_ms <= sort_ms {
            knee = Some(n);
        }
        println!("{n:>10}  {sort_ms:>14.4}  {radix_ms:>14.4}  {:>7.2}x", sort_ms / radix_ms);
        let mut row = Json::obj();
        row.set("keys", json::num(n as f64))
            .set("rank", json::num(rank as f64))
            .set("sort_ms_per_call", json::num(sort_ms))
            .set("radix_ms_per_call", json::num(radix_ms))
            .set("select_speedup", json::num(sort_ms / radix_ms))
            .set(
                "radix_warm_alloc_bytes_per_call",
                json::num(warm.bytes as f64 / iters as f64),
            );
        sel_rows.push(row);
    }
    match knee {
        Some(n) => println!("knee: radix overtakes the sort path at {n} keys"),
        None => println!("knee: not reached on these sizes (sort path still ahead)"),
    }

    // --- tree aggregation case (ISSUE 7): group partial sums combine up
    // a fixed-shape binary tree. The streaming reducer and the pairwise
    // parallel executor walk the SAME tree, so their sums must be
    // bit-identical; with chunk-sharding on, no reduction buffer reaches
    // model size (asserted via max_chunk_len). The flat left fold is a
    // timing reference only — the tree owns the canonical bit pattern.
    let agg_n = if quick { 20_000 } else { 200_000 };
    let agg_groups = 64usize;
    let agg_chunk = 4_096usize;
    let mut agg_rng = Rng::new(0xA66);
    let group_updates: Vec<Vec<f32>> = (0..agg_groups)
        .map(|_| (0..agg_n).map(|_| agg_rng.normal() as f32).collect())
        .collect();
    let build_shards = || -> Vec<AggregatorShard> {
        group_updates
            .iter()
            .enumerate()
            .map(|(g, u)| {
                let mut s = AggregatorShard::with_chunk(g, agg_n, agg_chunk, vec![g]);
                s.fold(g, u, 1.0);
                s
            })
            .collect()
    };

    let t0 = Instant::now();
    let mut flat = vec![0.0f64; agg_n];
    for u in &group_updates {
        for (a, &x) in flat.iter_mut().zip(u) {
            *a += x as f64;
        }
    }
    std::hint::black_box(&flat);
    let fold_ms = t0.elapsed().as_secs_f64() * 1e3;
    drop(flat);

    // streaming reducer (what round_inner drives): reduce phase only —
    // shards are prebuilt, so alloc/peak deltas isolate the combine work
    let shards = build_shards();
    let a0 = alloc_count::snapshot();
    alloc_count::reset_peak();
    let live0 = alloc_count::live_bytes();
    let t0 = Instant::now();
    let mut red = ShardReducer::with_chunk(agg_n, agg_groups, agg_chunk);
    for s in shards {
        red.push(s).unwrap();
    }
    let (stream_sum, stream_folded) = red.finish().unwrap();
    let stream_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stream_alloc = alloc_count::snapshot().since(&a0);
    let stream_peak_delta = alloc_count::peak_bytes().saturating_sub(live0);

    // parallel pairwise execution of the same tree
    let shards = build_shards();
    let a0 = alloc_count::snapshot();
    alloc_count::reset_peak();
    let live0 = alloc_count::live_bytes();
    let t0 = Instant::now();
    let (tree_sum, tree_folded) =
        reduce_shards_parallel(agg_n, agg_groups, agg_chunk, shards, par_workers).unwrap();
    let tree_ms = t0.elapsed().as_secs_f64() * 1e3;
    let tree_alloc = alloc_count::snapshot().since(&a0);
    let tree_peak_delta = alloc_count::peak_bytes().saturating_sub(live0);

    assert_eq!(stream_folded, tree_folded);
    assert!(
        stream_sum.iter().zip(tree_sum.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
        "parallel tree execution must be bit-identical to the streaming reducer"
    );
    assert!(
        stream_sum.max_chunk_len() <= agg_chunk,
        "chunk-sharded reduction must not hold a model-sized buffer \
         (chunk {} > {agg_chunk})",
        stream_sum.max_chunk_len()
    );
    println!(
        "\n== bench: tree aggregation ({agg_groups} groups x {agg_n} params, chunk {agg_chunk}) ==\n\
         {fold_ms:>10.2} ms flat fold (reference)  {stream_ms:>8.2} ms streaming  \
         {tree_ms:>8.2} ms tree x{par_workers}\n\
         reduce-phase alloc: {:.0} B streaming / {:.0} B tree; \
         peak delta: {stream_peak_delta} B streaming / {tree_peak_delta} B tree",
        stream_alloc.bytes as f64, tree_alloc.bytes as f64
    );

    let mut out = Json::obj();
    out.set("bench", json::s("engine_round"))
        .set("task", json::s("har"))
        .set("trainer", json::s("native"))
        .set("quick", Json::Bool(quick))
        // this binary always measures; `true` marks hand-authored files
        // committed from environments without a toolchain
        .set("placeholder", Json::Bool(false))
        .set("host_workers", json::num(par_workers as f64));
    let rows: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("devices", json::num(c.devices as f64))
                .set("participants", json::num(c.participants as f64))
                .set("workers", json::num(c.par_workers as f64))
                .set("speedup", json::num(c.seq.ms / c.par.ms));
            // seq_/par_ prefixes expand to seq_ms_per_round etc.
            for (k, v) in measured_json(&c.seq) {
                o.set(&format!("seq_{k}"), v);
            }
            for (k, v) in measured_json(&c.par) {
                o.set(&format!("par_{k}"), v);
            }
            o
        })
        .collect();
    out.set("cases", Json::Arr(rows));
    let mut cache_row = Json::obj();
    cache_row
        .set("devices", json::num(1_000.0))
        .set("participants", json::num(100.0))
        .set("clusters", json::num(3.0))
        .set("encode_requests_per_round", json::num(m.encode_requests))
        .set("encode_calls_per_round", json::num(m.encode_calls))
        .set("encode_reduction", json::num(reduction))
        .set("alloc_bytes_per_round", json::num(m.alloc_bytes));
    out.set("encode_cache", cache_row);
    let mut pool_row = Json::obj();
    pool_row
        .set("rounds", json::num(pool_rounds as f64))
        .set("workers", json::num(pool_workers_used as f64))
        .set("trainer_builds", json::num(trainer_builds as f64))
        .set("builds_reduction", json::num(builds_reduction));
    out.set("pool", pool_row);
    let mut cross_row = Json::obj();
    cross_row
        .set("rounds", json::num(cross_rounds as f64))
        .set("dropout", json::num(1.0))
        .set("download_requests", json::num(cst.download_requests as f64))
        .set("download_encodes", json::num(cst.download_encodes as f64))
        .set("cache_cross_round_hits", json::num(cst.cache_cross_round_hits as f64));
    out.set("cross_round_cache", cross_row);
    let mut sa_row = Json::obj();
    sa_row
        .set("devices", json::num(1_000.0))
        .set("rounds", json::num(sa_rounds as f64))
        .set("depth", json::num(2.0))
        .set("staleness_bound", json::num(2.0))
        .set("barrier_round_s_mean", json::num(barrier_round_s))
        .set("overlap_round_s_mean", json::num(overlap_round_s))
        .set("round_s_reduction", json::num(round_s_reduction))
        .set("barrier_ms_per_round", json::num(barrier_ms))
        .set("overlap_ms_per_round", json::num(overlap_ms));
    out.set("semi_async", sa_row);
    let mut sel = Json::obj();
    sel.set("cases", Json::Arr(sel_rows)).set(
        "knee_keys",
        knee.map(|n| json::num(n as f64)).unwrap_or(Json::Null),
    );
    out.set("selection_scale", sel);
    let mut agg_row = Json::obj();
    agg_row
        .set("n_params", json::num(agg_n as f64))
        .set("groups", json::num(agg_groups as f64))
        .set("chunk", json::num(agg_chunk as f64))
        .set("workers", json::num(par_workers as f64))
        .set("fold_baseline_ms", json::num(fold_ms))
        .set("stream_ms", json::num(stream_ms))
        .set("tree_ms", json::num(tree_ms))
        .set("stream_reduce_alloc_bytes", json::num(stream_alloc.bytes as f64))
        .set("tree_reduce_alloc_bytes", json::num(tree_alloc.bytes as f64))
        .set("stream_peak_delta_bytes", json::num(stream_peak_delta as f64))
        .set("tree_peak_delta_bytes", json::num(tree_peak_delta as f64))
        .set("max_chunk_len", json::num(stream_sum.max_chunk_len() as f64));
    out.set("tree_agg", agg_row);
    std::fs::write("BENCH_engine.json", out.to_string()).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}

//! Sequential vs parallel round-engine benchmark at fleet scale.
//!
//! Runs full communication rounds (plan → download codec → local SGD →
//! upload codec → sharded aggregation) on the HAR stand-in with the fleet
//! scaled to 100 / 1 000 / 10 000 simulated devices (α = 0.1 → 10 / 100 /
//! 1 000 participants per round), once with `engine.workers = 1` (the
//! sequential baseline) and once with one worker per host core. The two
//! paths produce bit-identical models (pinned by tests/engine_parity.rs),
//! so the speedup is free.
//!
//! Results are written to BENCH_engine.json in the current directory.
//! Quick mode: CAESAR_BENCH_QUICK=1 (fewer rounds, skips the 10k scale).

use std::time::Instant;

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::fleet::FleetKind;
use caesar_fl::schemes;
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::threadpool::workers;

struct Case {
    devices: usize,
    participants: usize,
    seq_ms: f64,
    par_ms: f64,
    par_workers: usize,
}

fn cfg_at(devices: usize, engine_workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.fleet = FleetKind::JetsonScaled(devices);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    // enough data that every device holds a shard even at 10k devices
    cfg.n_train = (4 * devices).max(8_000);
    cfg.n_test = 200;
    cfg.tau = 5;
    cfg.eval_every = usize::MAX; // eval is benchmarked elsewhere
    cfg.engine.workers = engine_workers;
    cfg
}

/// Mean host milliseconds per round over `rounds` timed rounds (after one
/// warm-up round).
fn ms_per_round(devices: usize, engine_workers: usize, rounds: usize) -> f64 {
    let cfg = cfg_at(devices, engine_workers);
    let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
    srv.step(1).unwrap(); // warm-up: first-touch allocations, locals fill
    let t0 = Instant::now();
    for t in 2..2 + rounds {
        srv.step(t).unwrap();
    }
    t0.elapsed().as_secs_f64() * 1e3 / rounds as f64
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let par_workers = workers(usize::MAX);
    let scales: &[usize] = if quick { &[100, 1_000] } else { &[100, 1_000, 10_000] };
    let rounds = |devices: usize| -> usize {
        match (quick, devices) {
            (true, _) => 2,
            (false, d) if d >= 10_000 => 3,
            _ => 5,
        }
    };

    println!("== bench: engine (sequential vs {par_workers} workers) ==");
    println!(
        "{:>8}  {:>12}  {:>12}  {:>12}  {:>8}",
        "devices", "participants", "seq ms/round", "par ms/round", "speedup"
    );
    let mut cases = Vec::new();
    for &n in scales {
        let r = rounds(n);
        let seq_ms = ms_per_round(n, 1, r);
        let par_ms = ms_per_round(n, par_workers, r);
        let participants = cfg_at(n, 1).participants_per_round();
        println!(
            "{n:>8}  {participants:>12}  {seq_ms:>12.1}  {par_ms:>12.1}  {:>7.2}x",
            seq_ms / par_ms
        );
        cases.push(Case { devices: n, participants, seq_ms, par_ms, par_workers });
    }

    let mut out = Json::obj();
    out.set("bench", json::s("engine_round"))
        .set("task", json::s("har"))
        .set("trainer", json::s("native"))
        .set("quick", Json::Bool(quick))
        // this binary always measures; `true` marks hand-authored files
        // committed from environments without a toolchain
        .set("placeholder", Json::Bool(false))
        .set("host_workers", json::num(par_workers as f64));
    let rows: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("devices", json::num(c.devices as f64))
                .set("participants", json::num(c.participants as f64))
                .set("seq_ms_per_round", json::num(c.seq_ms))
                .set("par_ms_per_round", json::num(c.par_ms))
                .set("workers", json::num(c.par_workers as f64))
                .set("speedup", json::num(c.seq_ms / c.par_ms));
            o
        })
        .collect();
    out.set("cases", Json::Arr(rows));
    std::fs::write("BENCH_engine.json", out.to_string()).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}

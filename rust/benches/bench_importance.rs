//! Planning-path benchmark: the per-round server-side decisions — Eq. 3
//! staleness ratios with K-means clustering, Eq. 4–6 importance ranking,
//! and Eq. 7–9 batch regulation — at fleet sizes up to 10k devices.

use caesar_fl::bench::Bench;
use caesar_fl::caesar::{cluster_download_ratios, optimize_batches, BatchPlanInput, ImportanceTable};
use caesar_fl::util::rng::Rng;

fn main() {
    let b = Bench::new("ImportanceTable::build (Eq. 4-6)").quick();
    for &n in &[100usize, 1_000, 10_000] {
        let mut rng = Rng::new(1);
        let volumes: Vec<usize> = (0..n).map(|_| rng.range_usize(10, 2000)).collect();
        let kls: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0).collect();
        b.case(&format!("n={n}"), n, || {
            std::hint::black_box(ImportanceTable::build(&volumes, &kls, 0.5));
        });
    }

    let b = Bench::new("cluster_download_ratios (Eq. 3 + K-means)").quick();
    for &n in &[8usize, 100, 1_000] {
        let mut rng = Rng::new(2);
        let st: Vec<usize> = (0..n).map(|_| rng.below(200)).collect();
        for k in [4usize, 16] {
            b.case(&format!("n={n} K={k}"), n, || {
                std::hint::black_box(cluster_download_ratios(&st, 500, 0.6, k));
            });
        }
    }

    let b = Bench::new("optimize_batches (Eq. 7-9)").quick();
    for &n in &[8usize, 100, 1_000] {
        let mut rng = Rng::new(3);
        let inputs: Vec<BatchPlanInput> = (0..n)
            .map(|_| BatchPlanInput {
                download_s: rng.f64() * 20.0,
                upload_s: rng.f64() * 20.0,
                mu: 1e-4 + rng.f64() * 1e-2,
            })
            .collect();
        b.case(&format!("n={n}"), n, || {
            std::hint::black_box(optimize_batches(&inputs, 30, 32));
        });
    }
}

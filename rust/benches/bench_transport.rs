//! Transport benchmark: frame codec throughput (encode/decode of the
//! round-dominating StartRound and EndRound frames at 1k / 64k / 1M
//! payload parameters, with allocation traffic per call) and localhost
//! Tcp round-trip latency (small control frame and a 64k-parameter
//! update echoed back).
//!
//! Results are written to BENCH_transport.json in the current directory
//! with `"placeholder": false` (the flag marks hand-authored files
//! committed from toolchain-less environments; this binary always
//! measures). Quick mode: CAESAR_BENCH_QUICK=1 (skips the 1M size).

use std::sync::Arc;
use std::time::{Duration, Instant};

use caesar_fl::bench::Bench;
use caesar_fl::coordinator::NetworkedStart;
use caesar_fl::engine::{RoundUpdate, StartRound};
use caesar_fl::fleet::RoundCost;
use caesar_fl::schemes::{DevicePlan, DownloadCodec, UploadCodec};
use caesar_fl::transport::{
    decode_frame, encode_frame, Conn, TcpConn, TcpTransport, Transport, WireMsg,
};
use caesar_fl::util::alloc_count::{self, CountingAlloc};
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::rng::Rng;
use caesar_fl::wire::Payload;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// A kickoff frame with an `n`-parameter Dense download payload.
fn start_msg(n: usize) -> WireMsg {
    let download = Arc::new(Payload::Dense(randn(n, 11)).encode());
    WireMsg::StartRound(Box::new(NetworkedStart {
        item: StartRound {
            t: 3,
            plan: DevicePlan {
                device: 1,
                download: DownloadCodec::Full,
                upload: UploadCodec::TopK { ratio: 0.9 },
                batch: 16,
                tau: 10,
            },
            beta_d: 5e6,
            beta_u: 2e6,
            mu: 3e-6,
        },
        lr: 0.05,
        rng: Rng::stream(42, 3, 1).state(),
        stream_base: 42,
        dropout_rate: 0.1,
        heartbeat_s: 10.0,
        sim_now_s: 123.5,
        prior_digest: Some(0x1234_5678_9ABC_DEF0),
        download,
    }))
}

/// A completion frame with an `n`-parameter model + Top-K upload.
fn update_msg(n: usize) -> WireMsg {
    let upload = UploadCodec::TopK { ratio: 0.9 }
        .encode_payload(&randn(n, 13), &mut Rng::new(9))
        .encode();
    WireMsg::EndRound {
        t: 3,
        update: Box::new(RoundUpdate {
            device: 1,
            w_final: randn(n, 12),
            upload,
            grad_norm: 1.25,
            loss: 0.7,
            down_wire_bits: n * 32,
            cost: RoundCost { download_s: 1.0, compute_s: 2.0, upload_s: 0.5 },
        }),
    }
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[1_000, 65_536] } else { &[1_000, 65_536, 1_048_576] };
    let mut codec_rows: Vec<Json> = Vec::new();

    for &n in sizes {
        let b = Bench::new(&format!("frame codec (P={n})")).quick();
        for (kind, msg) in [("start", start_msg(n)), ("update", update_msg(n))] {
            let bytes = encode_frame(&msg);
            let frame_bytes = bytes.len();

            let a0 = alloc_count::snapshot();
            let enc = b.case(&format!("{kind} encode"), n, || {
                std::hint::black_box(encode_frame(std::hint::black_box(&msg)));
            });
            let enc_alloc = alloc_count::snapshot().since(&a0);

            let a0 = alloc_count::snapshot();
            let dec = b.case(&format!("{kind} decode"), n, || {
                std::hint::black_box(decode_frame(std::hint::black_box(&bytes)).unwrap());
            });
            let dec_alloc = alloc_count::snapshot().since(&a0);

            let mut o = Json::obj();
            o.set("n_params", json::num(n as f64))
                .set("kind", json::s(kind))
                .set("frame_bytes", json::num(frame_bytes as f64))
                .set("encode_ns", json::num(enc.mean_ns))
                .set("encode_frames_per_s", json::num(1e9 / enc.mean_ns))
                .set("encode_allocs_per_frame", json::num(enc_alloc.count as f64 / enc.iters as f64))
                .set("decode_ns", json::num(dec.mean_ns))
                .set("decode_frames_per_s", json::num(1e9 / dec.mean_ns))
                .set("decode_allocs_per_frame", json::num(dec_alloc.count as f64 / dec.iters as f64));
            codec_rows.push(o);
        }
    }

    // --- localhost Tcp round-trip: echo server on an ephemeral port ---
    println!("\n== bench: tcp localhost round-trip ==");
    let mut lst = TcpTransport::bind("127.0.0.1:0").expect("bind");
    let addr = lst.socket_addr();
    let echo = std::thread::spawn(move || {
        let mut conn = lst
            .accept_timeout(Duration::from_secs(10))
            .expect("accept")
            .expect("client connects");
        while let Ok(Some(msg)) = conn.recv_timeout(Duration::from_secs(2)) {
            if conn.send(&msg).is_err() {
                break;
            }
        }
    });
    let mut conn = TcpConn::connect(addr).expect("connect");
    let mut rtt_rows: Vec<Json> = Vec::new();
    let reps = if quick { 200 } else { 1_000 };
    for (name, msg) in
        [("heartbeat", WireMsg::Heartbeat { device: 3, sim_t_s: 1.5 }), ("update-64k", update_msg(65_536))]
    {
        // warm-up
        for _ in 0..5 {
            conn.send(&msg).unwrap();
            conn.recv_timeout(Duration::from_secs(5)).unwrap().expect("echo");
        }
        let t0 = Instant::now();
        for _ in 0..reps {
            conn.send(&msg).unwrap();
            conn.recv_timeout(Duration::from_secs(5)).unwrap().expect("echo");
        }
        let us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
        println!("  {name:40} {reps:>7} it  mean rtt {us:>10.1} µs");
        let mut o = Json::obj();
        o.set("case", json::s(name)).set("rtt_us", json::num(us));
        rtt_rows.push(o);
    }
    drop(conn);
    echo.join().expect("echo thread");

    let mut out = Json::obj();
    out.set("bench", json::s("transport"))
        .set("quick", Json::Bool(quick))
        .set("placeholder", Json::Bool(false))
        .set("codec_cases", Json::Arr(codec_rows))
        .set("tcp_roundtrip", Json::Arr(rtt_rows));
    std::fs::write("BENCH_transport.json", out.to_string()).expect("write BENCH_transport.json");
    println!("wrote BENCH_transport.json");
}

//! Transport benchmark: frame codec throughput (encode/decode of the
//! round-dominating StartRound and EndRound frames at 1k / 64k / 1M
//! payload parameters, with allocation traffic per call), localhost
//! Tcp round-trip latency (small control frame and a 64k-parameter
//! update echoed back), and the `fleet_mux` serving-path case: 1000
//! device sessions packed onto {1000, 10, 1} connections, served once
//! by the readiness reactor and once by a classic sleep-poll sweep
//! loop, with wakeups counted for both (the reactor's scale with frames
//! delivered; the sweep's with elapsed-time × connections).
//!
//! Results are written to BENCH_transport.json in the current directory
//! with `"placeholder": false` (the flag marks hand-authored files
//! committed from toolchain-less environments; this binary always
//! measures). Quick mode: CAESAR_BENCH_QUICK=1 (skips the 1M size and
//! shrinks the fleet to 96 devices).

use std::sync::Arc;
use std::time::{Duration, Instant};

use caesar_fl::bench::Bench;
use caesar_fl::coordinator::NetworkedStart;
use caesar_fl::engine::{RoundUpdate, StartRound};
use caesar_fl::fleet::RoundCost;
use caesar_fl::schemes::{DevicePlan, DownloadCodec, UploadCodec};
use caesar_fl::transport::{
    decode_frame, encode_frame, Conn, RawSource, Reactor, TcpConn, TcpTransport, Transport,
    WireMsg,
};
use caesar_fl::util::alloc_count::{self, CountingAlloc};
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::rng::Rng;
use caesar_fl::wire::Payload;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// A kickoff frame with an `n`-parameter Dense download payload.
fn start_msg(n: usize) -> WireMsg {
    let download = Arc::new(Payload::Dense(randn(n, 11)).encode());
    WireMsg::StartRound(Box::new(NetworkedStart {
        item: StartRound {
            t: 3,
            plan: DevicePlan {
                device: 1,
                download: DownloadCodec::Full,
                upload: UploadCodec::TopK { ratio: 0.9 },
                batch: 16,
                tau: 10,
            },
            beta_d: 5e6,
            beta_u: 2e6,
            mu: 3e-6,
        },
        lr: 0.05,
        rng: Rng::stream(42, 3, 1).state(),
        stream_base: 42,
        dropout_rate: 0.1,
        heartbeat_s: 10.0,
        sim_now_s: 123.5,
        prior_digest: Some(0x1234_5678_9ABC_DEF0),
        download,
    }))
}

/// A completion frame with an `n`-parameter model + Top-K upload.
fn update_msg(n: usize) -> WireMsg {
    let upload = UploadCodec::TopK { ratio: 0.9 }
        .encode_payload(&randn(n, 13), &mut Rng::new(9))
        .encode();
    WireMsg::EndRound {
        t: 3,
        update: Box::new(RoundUpdate {
            device: 1,
            w_final: randn(n, 12),
            upload,
            grad_norm: 1.25,
            loss: 0.7,
            down_wire_bits: n * 32,
            cost: RoundCost { download_s: 1.0, compute_s: 2.0, upload_s: 0.5 },
        }),
    }
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[1_000, 65_536] } else { &[1_000, 65_536, 1_048_576] };
    let mut codec_rows: Vec<Json> = Vec::new();

    for &n in sizes {
        let b = Bench::new(&format!("frame codec (P={n})")).quick();
        for (kind, msg) in [("start", start_msg(n)), ("update", update_msg(n))] {
            let bytes = encode_frame(&msg);
            let frame_bytes = bytes.len();

            let a0 = alloc_count::snapshot();
            let enc = b.case(&format!("{kind} encode"), n, || {
                std::hint::black_box(encode_frame(std::hint::black_box(&msg)));
            });
            let enc_alloc = alloc_count::snapshot().since(&a0);

            let a0 = alloc_count::snapshot();
            let dec = b.case(&format!("{kind} decode"), n, || {
                std::hint::black_box(decode_frame(std::hint::black_box(&bytes)).unwrap());
            });
            let dec_alloc = alloc_count::snapshot().since(&a0);

            let mut o = Json::obj();
            o.set("n_params", json::num(n as f64))
                .set("kind", json::s(kind))
                .set("frame_bytes", json::num(frame_bytes as f64))
                .set("encode_ns", json::num(enc.mean_ns))
                .set("encode_frames_per_s", json::num(1e9 / enc.mean_ns))
                .set("encode_allocs_per_frame", json::num(enc_alloc.count as f64 / enc.iters as f64))
                .set("decode_ns", json::num(dec.mean_ns))
                .set("decode_frames_per_s", json::num(1e9 / dec.mean_ns))
                .set("decode_allocs_per_frame", json::num(dec_alloc.count as f64 / dec.iters as f64));
            codec_rows.push(o);
        }
    }

    // --- localhost Tcp round-trip: echo server on an ephemeral port ---
    println!("\n== bench: tcp localhost round-trip ==");
    let mut lst = TcpTransport::bind("127.0.0.1:0").expect("bind");
    let addr = lst.socket_addr();
    let echo = std::thread::spawn(move || {
        let mut conn = lst
            .accept_timeout(Duration::from_secs(10))
            .expect("accept")
            .expect("client connects");
        while let Ok(Some(msg)) = conn.recv_timeout(Duration::from_secs(2)) {
            if conn.send(&msg).is_err() {
                break;
            }
        }
    });
    let mut conn = TcpConn::connect(addr).expect("connect");
    let mut rtt_rows: Vec<Json> = Vec::new();
    let reps = if quick { 200 } else { 1_000 };
    for (name, msg) in
        [("heartbeat", WireMsg::Heartbeat { device: 3, sim_t_s: 1.5 }), ("update-64k", update_msg(65_536))]
    {
        // warm-up
        for _ in 0..5 {
            conn.send(&msg).unwrap();
            conn.recv_timeout(Duration::from_secs(5)).unwrap().expect("echo");
        }
        let t0 = Instant::now();
        for _ in 0..reps {
            conn.send(&msg).unwrap();
            conn.recv_timeout(Duration::from_secs(5)).unwrap().expect("echo");
        }
        let us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
        println!("  {name:40} {reps:>7} it  mean rtt {us:>10.1} µs");
        let mut o = Json::obj();
        o.set("case", json::s(name)).set("rtt_us", json::num(us));
        rtt_rows.push(o);
    }
    drop(conn);
    echo.join().expect("echo thread");

    // --- fleet_mux: N device sessions over C connections, reactor vs
    // sleep-poll serving loops -----------------------------------------
    println!("\n== bench: fleet_mux serving path ==");
    caesar_fl::transport::readiness::raise_fd_limit();
    let (devices, mux_rounds) = if quick { (96, 3) } else { (1_000, 10) };
    let topologies: &[usize] = if quick { &[96, 8, 1] } else { &[1_000, 10, 1] };
    let mut mux_rows: Vec<Json> = Vec::new();
    for &conns in topologies {
        let dpc = devices / conns;
        let reactor = serve_fleet_mux(conns, dpc, mux_rounds, ServeMode::Reactor);
        let sleep = serve_fleet_mux(conns, dpc, mux_rounds, ServeMode::SleepPoll);
        let ratio = sleep.wakeups as f64 / reactor.wakeups.max(1) as f64;
        println!(
            "  {conns:>5} conns x {dpc:>5} devices  reactor {:>9.0} fr/s {:>7.2} ms/round \
             {:>7} wakeups | sleep-poll {:>9.0} fr/s {:>7.2} ms/round {:>9} wakeups \
             ({ratio:.1}x)",
            reactor.frames_per_s,
            reactor.ms_per_round,
            reactor.wakeups,
            sleep.frames_per_s,
            sleep.ms_per_round,
            sleep.wakeups,
        );
        let mut o = Json::obj();
        o.set("conns", json::num(conns as f64))
            .set("devices_per_conn", json::num(dpc as f64))
            .set("frames_per_round", json::num((conns * dpc) as f64))
            .set("reactor_frames_per_s", json::num(reactor.frames_per_s))
            .set("reactor_ms_per_round", json::num(reactor.ms_per_round))
            .set("reactor_wakeups", json::num(reactor.wakeups as f64))
            .set("sleep_poll_frames_per_s", json::num(sleep.frames_per_s))
            .set("sleep_poll_ms_per_round", json::num(sleep.ms_per_round))
            .set("sleep_poll_wakeups", json::num(sleep.wakeups as f64))
            .set("wakeup_ratio", json::num(ratio));
        mux_rows.push(o);
    }

    let mut out = Json::obj();
    out.set("bench", json::s("transport"))
        .set("quick", Json::Bool(quick))
        .set("placeholder", Json::Bool(false))
        .set("codec_cases", Json::Arr(codec_rows))
        .set("tcp_roundtrip", Json::Arr(rtt_rows))
        .set("fleet_mux", Json::Arr(mux_rows));
    std::fs::write("BENCH_transport.json", out.to_string()).expect("write BENCH_transport.json");
    println!("wrote BENCH_transport.json");
}

#[derive(Clone, Copy, PartialEq)]
enum ServeMode {
    /// One readiness wait-set over every connection; wakeups =
    /// `Reactor::wakeups()` (scales with frames delivered).
    Reactor,
    /// The loop this PR deleted from the serving path: nap, then
    /// non-blocking-sweep every connection; wakeups = try_recv polls
    /// (scales with elapsed-time × connections).
    SleepPoll,
}

struct MuxStats {
    frames_per_s: f64,
    ms_per_round: f64,
    wakeups: u64,
}

/// Serve `rounds` synthetic rounds to `conns` connections carrying
/// `dpc` device sessions each: per round the server kicks every
/// connection with one frame, and every session answers with one
/// heartbeat — `conns * dpc` frames to collect per round. Training and
/// codec work are deliberately absent; this measures the serving loop.
fn serve_fleet_mux(conns: usize, dpc: usize, rounds: usize, mode: ServeMode) -> MuxStats {
    let mut lst = TcpTransport::bind("127.0.0.1:0").expect("bind");
    let addr = lst.socket_addr();
    let mut clients = Vec::with_capacity(conns);
    for c in 0..conns {
        clients.push(
            std::thread::Builder::new()
                .stack_size(128 * 1024)
                .spawn(move || {
                    let mut conn = TcpConn::connect(addr).expect("dial");
                    for r in 0..rounds {
                        // wait for this round's kick
                        loop {
                            match conn.recv_timeout(Duration::from_secs(5)) {
                                Ok(Some(_)) => break,
                                Ok(None) => continue,
                                Err(e) => panic!("client {c}: {e}"),
                            }
                        }
                        for d in 0..dpc {
                            conn.send(&WireMsg::Heartbeat {
                                device: c * dpc + d,
                                sim_t_s: r as f64,
                            })
                            .expect("heartbeat send");
                        }
                    }
                })
                .expect("spawn client"),
        );
    }
    let mut socks: Vec<TcpConn> = Vec::with_capacity(conns);
    while socks.len() < conns {
        if let Some(s) = lst.accept_timeout(Duration::from_secs(10)).expect("accept") {
            socks.push(s);
        }
    }

    let kick = WireMsg::JoinAck { device: 0, n_devices: dpc };
    let target = conns * dpc;
    let mut reactor = Reactor::new(None);
    let mut polls: u64 = 0;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for s in &mut socks {
            s.send(&kick).expect("kick send");
        }
        let mut got = 0usize;
        while got < target {
            match mode {
                ServeMode::Reactor => {
                    let sources: Vec<(u64, RawSource)> =
                        socks.iter().enumerate().map(|(i, s)| (i as u64, s.source())).collect();
                    let wake = reactor
                        .wait(lst.listener_source(), &sources, Duration::from_secs(5))
                        .expect("reactor wait");
                    let tokens: Vec<u64> =
                        if wake.sweep { (0..conns as u64).collect() } else { wake.ready };
                    for tok in tokens {
                        let s = &mut socks[tok as usize];
                        while let Some(_msg) = s.try_recv().expect("drain") {
                            got += 1;
                        }
                    }
                }
                ServeMode::SleepPoll => {
                    std::thread::sleep(Duration::from_millis(2));
                    for s in socks.iter_mut() {
                        polls += 1;
                        while let Some(_msg) = s.try_recv().expect("sweep") {
                            got += 1;
                            polls += 1;
                        }
                    }
                }
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    for h in clients {
        h.join().expect("client thread");
    }
    MuxStats {
        frames_per_s: (target * rounds) as f64 / elapsed,
        ms_per_round: elapsed * 1e3 / rounds as f64,
        wakeups: match mode {
            ServeMode::Reactor => reactor.wakeups(),
            ServeMode::SleepPoll => polls,
        },
    }
}

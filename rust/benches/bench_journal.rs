//! Journal benchmark: framed-append throughput (the per-decision cost a
//! journaled run pays) for the round-dominating EndRound record and for
//! model-sized snapshots, against both the in-memory sink and the
//! flush-per-record file sink, plus the recovery scan's bytes/s — the
//! restart-latency number.
//!
//! Results are written to BENCH_journal.json in the current directory
//! with `"placeholder": false` (the flag marks hand-authored files
//! committed from toolchain-less environments; this binary always
//! measures). Quick mode: CAESAR_BENCH_QUICK=1 (skips the 64k-param
//! snapshot and shrinks the recovery image).

use caesar_fl::bench::Bench;
use caesar_fl::coordinator::RoundRecord;
use caesar_fl::journal::{
    self, EndRound, JournalSink, ParamBlock, Record, RoundClose, RoundOpen, RunHeader, Snapshot,
    VecSink, JOURNAL_VERSION,
};
use caesar_fl::config::{ExperimentConfig, TrainerBackend};
use caesar_fl::fleet::FleetKind;
use caesar_fl::schemes::{DownloadCodec, UploadCodec};
use caesar_fl::util::alloc_count::{self, CountingAlloc};
use caesar_fl::util::json::{self, Json};
use caesar_fl::util::rng::{Rng, RngState};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn end_record(t: usize) -> Record {
    Record::EndRound(EndRound {
        t,
        fold_t: t,
        device: 2,
        w_digest: 0xDEAD_BEEF_0BAD_F00D,
        upload_bits: 52_412,
        down_wire_bits: 131_072,
        grad_norm: 1.25,
        loss: 0.7,
        download_s: 0.8,
        compute_s: 2.4,
        upload_s: 0.3,
    })
}

fn snapshot_record(t: usize, n_params: usize, n_dev: usize) -> Record {
    Record::Snapshot(Box::new(Snapshot {
        t,
        model_version: t as u64,
        sim_time_s: t as f64 * 42.0,
        rng: RngState { s: [1, 2, 3, 4], spare_normal: None },
        down_bits: 1e9,
        up_bits: 4e8,
        model: ParamBlock::new(randn(n_params, 17)),
        locals: (0..n_dev).map(|d| Some(ParamBlock::new(randn(n_params, d as u64)))).collect(),
        grad_norms: (0..n_dev).map(|d| d as f64).collect(),
        last_round: vec![t; n_dev],
    }))
}

/// A small synthetic run image for the recovery-scan case.
fn image(rounds: usize, n_params: usize) -> Vec<u8> {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.fleet = FleetKind::JetsonScaled(4);
    let mut recs = vec![Record::RunHeader(RunHeader {
        version: JOURNAL_VERSION,
        scheme: "caesar".to_string(),
        snapshot_every: 10,
        cfg,
    })];
    recs.push(snapshot_record(0, n_params, 4));
    for t in 1..=rounds {
        recs.push(Record::RoundOpen(RoundOpen {
            t,
            model_version: t as u64 - 1,
            sim_now_s: t as f64,
            lr: 0.05,
            stream_base: 42,
            plans: (0..3)
                .map(|d| journal::PlanEntry {
                    device: d,
                    download: DownloadCodec::CaesarSplit { ratio: 0.4 },
                    upload: UploadCodec::TopK { ratio: 0.5 },
                    batch: 16,
                    tau: 5,
                    beta_d: 1e6,
                    beta_u: 5e5,
                    mu: 1e-4,
                })
                .collect(),
        }));
        for _ in 0..3 {
            recs.push(end_record(t));
        }
        recs.push(Record::RoundClose(RoundClose {
            t,
            completers: 3,
            model_version: t as u64,
            model_digest: t as u64 * 31,
            down_bits: t as f64 * 4096.0,
            up_bits: t as f64 * 1024.0,
            rec: RoundRecord { t, participants: 3, ..RoundRecord::default() },
        }));
        if t % 10 == 0 {
            recs.push(snapshot_record(t, n_params, 4));
        }
    }
    recs.iter().flat_map(journal::encode_record).collect()
}

fn main() {
    let quick = std::env::var("CAESAR_BENCH_QUICK").is_ok();
    let mut rows: Vec<Json> = Vec::new();

    // --- append throughput, in-memory sink ---
    let b = Bench::new("journal append").quick();
    let snap_sizes: &[usize] = if quick { &[1_000] } else { &[1_000, 65_536] };
    let mut cases: Vec<(String, Record)> = vec![("end-round".to_string(), end_record(3))];
    for &n in snap_sizes {
        cases.push((format!("snapshot-{n}p"), snapshot_record(4, n, 4)));
    }
    for (name, rec) in &cases {
        let frame_bytes = journal::encode_record(rec).len();
        let mut sink = VecSink::default();
        let a0 = alloc_count::snapshot();
        let st = b.case(&format!("{name} (VecSink)"), frame_bytes, || {
            // bound the buffer so the case measures appends, not growth
            if sink.buf.len() > 1 << 26 {
                sink.buf.clear();
            }
            sink.append(&journal::encode_record(std::hint::black_box(rec))).unwrap();
        });
        let alloc = alloc_count::snapshot().since(&a0);
        let mut o = Json::obj();
        o.set("case", json::s(&format!("{name}-vec")))
            .set("frame_bytes", json::num(frame_bytes as f64))
            .set("append_ns", json::num(st.mean_ns))
            .set("appends_per_s", json::num(1e9 / st.mean_ns))
            .set("mb_per_s", json::num(frame_bytes as f64 * 1e9 / st.mean_ns / 1e6))
            .set("allocs_per_append", json::num(alloc.count as f64 / st.iters as f64))
            .set("alloc_bytes_per_append", json::num(alloc.bytes as f64 / st.iters as f64));
        rows.push(o);
    }

    // --- append throughput, flush-per-record file sink ---
    let path = std::env::temp_dir().join(format!("caesar_bench_journal_{}.cjl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut fsink = journal::FileSink::create(&path).expect("create bench journal");
    let rec = end_record(3);
    let frame_bytes = journal::encode_record(&rec).len();
    let a0 = alloc_count::snapshot();
    let st = b.case("end-round (FileSink, flush/record)", frame_bytes, || {
        fsink.append(&journal::encode_record(std::hint::black_box(&rec))).unwrap();
    });
    let alloc = alloc_count::snapshot().since(&a0);
    let mut o = Json::obj();
    o.set("case", json::s("end-round-file"))
        .set("frame_bytes", json::num(frame_bytes as f64))
        .set("append_ns", json::num(st.mean_ns))
        .set("appends_per_s", json::num(1e9 / st.mean_ns))
        .set("allocs_per_append", json::num(alloc.count as f64 / st.iters as f64));
    rows.push(o);
    drop(fsink);
    let _ = std::fs::remove_file(&path);

    // --- recovery scan: restart latency per journal byte ---
    let rounds = if quick { 100 } else { 1_000 };
    let img = image(rounds, 1_000);
    let n_records = journal::recover(&img).records.len();
    let b = Bench::new("journal recover").quick();
    let st = b.case(&format!("scan {rounds}-round image"), img.len(), || {
        std::hint::black_box(journal::recover(std::hint::black_box(&img)));
    });
    let mut recover_row = Json::obj();
    recover_row
        .set("image_bytes", json::num(img.len() as f64))
        .set("records", json::num(n_records as f64))
        .set("scan_ns", json::num(st.mean_ns))
        .set("mb_per_s", json::num(img.len() as f64 * 1e9 / st.mean_ns / 1e6));

    let mut out = Json::obj();
    out.set("bench", json::s("journal"))
        .set("quick", Json::Bool(quick))
        .set("placeholder", Json::Bool(false))
        .set("append_cases", Json::Arr(rows))
        .set("recover", recover_row);
    std::fs::write("BENCH_journal.json", out.to_string()).expect("write BENCH_journal.json");
    println!("wrote BENCH_journal.json");
}

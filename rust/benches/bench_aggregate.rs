//! Aggregation hot-loop benchmark: summing K compressed gradients into
//! the global update at paper-scale parameter counts. The PS does this
//! once per round over every participant; it must stay far below the
//! simulated round time.

use caesar_fl::bench::Bench;
use caesar_fl::compress::topk_sparsify;
use caesar_fl::util::rng::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() {
    for &n in &[100_000usize, 1_000_000] {
        let b = Bench::new(&format!("aggregate K dense gradients (P={n})")).quick();
        for k in [8usize, 30] {
            let grads: Vec<Vec<f32>> = (0..k).map(|i| randn(n, i as u64)).collect();
            let mut agg = vec![0.0f64; n];
            b.case(&format!("K={k}"), n * k, || {
                agg.iter_mut().for_each(|a| *a = 0.0);
                for g in &grads {
                    for (a, &x) in agg.iter_mut().zip(g) {
                        *a += x as f64;
                    }
                }
                std::hint::black_box(&agg);
            });
        }

        let b = Bench::new(&format!("aggregate K top-k-sparse gradients (P={n})")).quick();
        for k in [8usize, 30] {
            let grads: Vec<Vec<f32>> = (0..k)
                .map(|i| topk_sparsify(&randn(n, 100 + i as u64), 0.6).dense)
                .collect();
            let mut agg = vec![0.0f64; n];
            b.case(&format!("K={k} θ=0.6"), n * k, || {
                agg.iter_mut().for_each(|a| *a = 0.0);
                for g in &grads {
                    for (a, &x) in agg.iter_mut().zip(g) {
                        *a += x as f64;
                    }
                }
                std::hint::black_box(&agg);
            });
        }
    }

    // the global model update that follows aggregation
    let b = Bench::new("global model update w -= mean(agg)").quick();
    for &n in &[100_000usize, 1_000_000] {
        let mut w = randn(n, 7);
        let agg: Vec<f64> = randn(n, 8).iter().map(|&x| x as f64).collect();
        b.case(&format!("P={n}"), n, || {
            for (wi, &a) in w.iter_mut().zip(&agg) {
                *wi -= (a / 8.0) as f32;
            }
            std::hint::black_box(&w);
        });
    }
}

//! Codec hot-path benchmark: Caesar model compress/recover, Top-K
//! sparsification and stochastic quantization across payload sizes —
//! the L3 per-participant work on every round's critical path.

use caesar_fl::bench::Bench;
use caesar_fl::compress::{
    abs_sort_keys, caesar_compress, caesar_recover, quantize_stochastic, select_threshold,
    topk_sparsify,
};
use caesar_fl::util::rng::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() {
    let sizes = [10_000usize, 100_000, 1_000_000];

    let b = Bench::new("caesar_compress (θ=0.35)").quick();
    for &n in &sizes {
        let w = randn(n, 1);
        b.case(&format!("n={n}"), n, || {
            std::hint::black_box(caesar_compress(std::hint::black_box(&w), 0.35));
        });
    }

    let b = Bench::new("caesar_recover (θ=0.35)").quick();
    for &n in &sizes {
        let w = randn(n, 2);
        let local = randn(n, 3);
        let cm = caesar_compress(&w, 0.35);
        b.case(&format!("n={n}"), n, || {
            std::hint::black_box(caesar_recover(std::hint::black_box(&cm), &local));
        });
    }

    let b = Bench::new("topk_sparsify").quick();
    for &n in &sizes {
        let g = randn(n, 4);
        for ratio in [0.1, 0.6] {
            b.case(&format!("n={n} θ={ratio}"), n, || {
                std::hint::black_box(topk_sparsify(std::hint::black_box(&g), ratio));
            });
        }
    }

    // threshold selection underneath topk/caesar: O(n) radix select vs
    // the old sort-order select_nth_unstable, on identical u32 keys
    let b = Bench::new("threshold select (rank = 0.99·n)").quick();
    for &n in &sizes {
        let g = randn(n, 8);
        let rank = ((n as f64 * 0.99) as usize).min(n - 1);
        b.case(&format!("radix n={n}"), n, || {
            std::hint::black_box(select_threshold(std::hint::black_box(&g), rank));
        });
        let mut keys: Vec<u32> = Vec::new();
        b.case(&format!("sort n={n}"), n, || {
            abs_sort_keys(std::hint::black_box(&g), &mut keys);
            let (_, kth, _) = keys.select_nth_unstable(rank);
            std::hint::black_box(*kth);
        });
    }

    let b = Bench::new("quantize_stochastic (4 bits)").quick();
    for &n in &sizes {
        let x = randn(n, 5);
        let noise: Vec<f32> = randn(n, 6).iter().map(|v| v.abs().fract()).collect();
        b.case(&format!("n={n}"), n, || {
            std::hint::black_box(quantize_stochastic(std::hint::black_box(&x), 15, &noise));
        });
    }

    let b = Bench::new("wire encode/decode (n=100k, θ=0.35)").quick();
    let w = randn(100_000, 7);
    let cm = caesar_compress(&w, 0.35);
    let bytes = cm.encode();
    b.case("encode", 100_000, || {
        std::hint::black_box(cm.encode());
    });
    b.case("decode", 100_000, || {
        std::hint::black_box(caesar_fl::compress::CompressedModel::decode(&bytes, 100_000));
    });
}
